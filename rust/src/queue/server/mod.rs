//! TCP server hosting the QueueServer and/or DataServer (paper Figure 2).
//!
//! # Architecture: readiness-driven core (unix)
//!
//! Event-loop shards own the accepted sockets and multiplex them through
//! a pluggable readiness backend (`poll(2)` or `epoll`, hand-rolled FFI:
//! the crate's no-new-deps rule rules out `mio`/`libc`, and `std`
//! exposes no readiness API). Decoded requests are executed by a small
//! fixed pool of worker threads against the shared [`QueueService`] +
//! [`Store`]; workers never sleep inside an op. A connection walks
//!
//! ```text
//! assembling --frame--> executing --would-block--> parked --waker/deadline--+
//!      ^                    |                                               |
//!      +------(writing, while the response drains)<---final/ready-----------+
//! ```
//!
//! * **assembling** — nonblocking reads feed a resumable
//!   [`FrameAssembler`]; a stalled or hostile peer costs one idle fd, not
//!   a pinned thread (slow-loris containment).
//! * **executing** — the frame is in the worker pool; the socket is not
//!   watched meanwhile (the protocol is synchronous: one request in
//!   flight per connection; pipelined bytes wait in the kernel buffer).
//! * **parked** — a blocking op (Consume / ConsumeMany / WaitVersion)
//!   found nothing. The worker registers a [`crate::queue::ReadyWaker`]
//!   with the broker or store FIRST, then re-checks with a zero timeout,
//!   so a publish landing in between cannot be a lost wakeup. A parked
//!   connection holds no thread; a wake or the op's deadline
//!   re-dispatches it.
//! * **writing** — responses are written nonblockingly; leftovers wait
//!   for writability. While a response is draining the socket is not
//!   read, so a slow reader backpressures itself to one buffered
//!   response (bounded memory per connection).
//!
//! Two lifecycle guards keep the connection table honest at volunteer
//! scale: parked sockets stay readable in the interest set, so a
//! consumer that dies mid-wait is torn down — and its broker/store
//! waiter registration cancelled — the moment the kernel reports the
//! hangup rather than at park-deadline expiry; and
//! [`ServerOptions::idle_timeout`] rides the (lazily invalidated,
//! self-compacting) timer heap to reap connections with no frame
//! activity, counted in `server.conns_reaped`. Parked consumers are
//! exempt from reaping: a blocked Consume **is** activity.
//!
//! # Readiness backends and event-loop sharding
//!
//! The readiness layer is the [`poller::Poller`] trait — register /
//! modify / deregister fds under caller tokens, wait for events — with
//! two hand-rolled FFI implementations selected by
//! [`ServerOptions::poller`]:
//!
//! * **`poll`** (every unix; the non-Linux default) rebuilds an O(open)
//!   fd array per wait and the kernel rescans all of it.
//! * **`epoll`** (Linux; what `auto` picks there) keeps the interest set
//!   in the kernel, so a wait costs O(ready) — the backend that carries
//!   50k+ mostly-idle volunteers.
//!
//! Both are level-triggered: unconsumed readiness is simply re-reported,
//! which the loop's one-frame-per-round fairness budget relies on. The
//! trait contract has one sharp edge — an EMPTY interest must report
//! nothing at all (not even errors), because a connection mid-execute
//! owns a waiter registration that only the verdict may release; epoll
//! cannot mask ERR/HUP, so its backend maps empty interest to
//! `EPOLL_CTL_DEL`.
//!
//! [`ServerOptions::loop_shards`] = N runs N event-loop threads, each
//! owning its own connections, timer heaps, and waker registrations. On
//! Linux every shard gets its own `SO_REUSEPORT` listener and the kernel
//! balances accepts by connection-tuple hash — note the caveat: hash
//! balancing ignores shard load, so a slow shard still receives its
//! share (the per-shard `server.shard<i>.*` obs rows make that
//! visible). Elsewhere — or if the reuseport binds fail — shard 0
//! accepts and round-robins sockets to its peers through their wake
//! pipes. `max_connections` stays a global cap; `max_conns_per_ip` is
//! enforced per shard (worst case a peer holds `loop_shards *` the
//! cap).
//!
//! Every layer of the loop feeds the process-wide [`crate::obs`]
//! registry (per-op queue-wait/execute latency, poll round duration,
//! live/parked connection gauges, read-budget, backpressure and
//! accept-backoff counters, per-shard breakdowns), served live by
//! `Op::Metrics`.
//!
//! A background sweeper still requeues expired unACKed deliveries every
//! 100 ms; its requeues fire the queue wakers, so parked consumers keep
//! their at-most-100 ms-late redelivery semantics.
//!
//! `Shutdown` (op or [`ServerHandle::shutdown`]) closes the listeners
//! immediately, gives parked ops a final attempt, bound-waits for
//! in-flight work and response flushes, then joins the shards, the
//! workers, and the sweeper — no detached threads survive a shutdown.
//!
//! Non-unix targets keep the previous thread-per-connection loop as a
//! degraded fallback: same wire semantics, none of the scaling.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::data::{DataApi, Store};
use crate::obs;
use crate::queue::job::{JobQueueApi, JobQuota, QuotaExceeded};
use crate::queue::wire::{
    put_bytes, put_str, put_u32, read_frame, write_frame, BodyReader, Op, MAX_FRAME, ST_NONE,
    ST_OK, ST_QUOTA,
};
use crate::queue::{QueueApi, QueueService};

#[cfg(not(unix))]
use crate::queue::wire::ST_ERR;

pub mod poller;

#[cfg(unix)]
mod poll_backend;

#[cfg(target_os = "linux")]
mod epoll_backend;

#[cfg(unix)]
mod shard;

pub use poller::PollerKind;

#[cfg(unix)]
use std::os::unix::net::UnixStream;
#[cfg(unix)]
use std::sync::atomic::AtomicUsize;
#[cfg(unix)]
use std::sync::{mpsc, Mutex};

#[cfg(unix)]
use self::poller::make_poller;
#[cfg(unix)]
use self::shard::{worker_loop, AcceptMode, LoopSignal, Shard, ShardSetup, Work};

/// Tuning for [`serve_with`]; `Default` matches [`serve`].
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Worker threads executing decoded ops (0 = one per CPU, capped at
    /// 8). Workers never block inside an op, so a handful covers thousands
    /// of connections.
    pub workers: usize,
    /// Cap on concurrently accepted connections — global across shards.
    /// At the cap the listeners are simply not watched: excess connects
    /// wait in the OS backlog until a slot frees (no accept-then-close
    /// churn).
    pub max_connections: usize,
    /// Shutdown bound-wait: how long the event loop waits for in-flight
    /// ops to finish and response buffers to flush before closing.
    pub drain_wait: Duration,
    /// Reap connections with no frame activity for this long (`None` =
    /// never). Parked consumers are exempt — a blocked Consume is
    /// activity — so only half-open or abandoned sockets are collected.
    pub idle_timeout: Option<Duration>,
    /// Cap on live connections from any single peer IP (0 = unlimited).
    /// Unlike `max_connections`, which parks excess connects in the OS
    /// backlog, a per-IP violation REFUSES the connection outright
    /// (accept + immediate close, counted by `server.conns_refused`) —
    /// otherwise one misbehaving volunteer saturating the global cap
    /// would starve every other peer's place in the backlog. Enforced
    /// per shard when `loop_shards > 1`.
    pub max_conns_per_ip: usize,
    /// Event-loop shards (clamped to 1..=[`obs::MAX_SHARDS`]). Each
    /// shard is one loop thread with its own connections and timers; on
    /// Linux each gets an `SO_REUSEPORT` listener, elsewhere shard 0
    /// accepts and distributes. 1 = the classic single-loop server.
    pub loop_shards: usize,
    /// Readiness backend; [`PollerKind::Auto`] picks `epoll` on Linux
    /// and `poll` elsewhere.
    pub poller: PollerKind,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            workers: 0,
            max_connections: 16_384,
            drain_wait: Duration::from_secs(5),
            idle_timeout: None,
            max_conns_per_ip: 0,
            loop_shards: 1,
            poller: PollerKind::Auto,
        }
    }
}

#[cfg(unix)]
impl ServerOptions {
    fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    }
}

/// A running server; dropping does NOT stop it — call [`ServerHandle::shutdown`].
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    #[cfg(unix)]
    signals: Vec<Arc<LoopSignal>>,
    /// Shards first, workers, then sweeper — join order matters: the
    /// exiting shards drop the work channel, which releases the workers.
    threads: Vec<std::thread::JoinHandle<()>>,
    /// The hosted queue backend (plain [`crate::queue::broker::Broker`] or
    /// [`crate::queue::durability::DurableBroker`]).
    pub broker: Arc<dyn QueueService>,
    pub store: Arc<Store>,
}

/// Where a self-poke connects: a wildcard bind address (0.0.0.0 / ::) is
/// not connectable on every platform (Windows refuses it), so rewrite an
/// unspecified IP to the loopback of the same family.
#[cfg(not(unix))]
fn poke_addr(mut addr: std::net::SocketAddr) -> std::net::SocketAddr {
    if addr.ip().is_unspecified() {
        addr.set_ip(if addr.is_ipv4() {
            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
        } else {
            std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
        });
    }
    addr
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        #[cfg(unix)]
        for signal in &self.signals {
            signal.notify();
        }
        #[cfg(not(unix))]
        {
            // Unpark the blocking accept loop with a throwaway connection.
            let _ = TcpStream::connect(poke_addr(self.addr));
        }
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }

    /// True once a Shutdown op (or [`ServerHandle::shutdown`]) stopped the
    /// server — lets a CLI host block until remotely shut down.
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// Serve `broker` + `store` on `addr` (use port 0 for an ephemeral port)
/// with default [`ServerOptions`].
pub fn serve(addr: &str, broker: Arc<dyn QueueService>, store: Arc<Store>) -> Result<ServerHandle> {
    serve_with(addr, broker, store, ServerOptions::default())
}

/// Visibility sweeper: the lazy in-op sweep covers active brokers; this
/// timer covers idle periods (all volunteers gone mid-batch). Its requeues
/// fire queue wakers, so parked remote consumers re-check too.
fn spawn_sweeper(
    broker: Arc<dyn QueueService>,
    stop: Arc<AtomicBool>,
) -> Result<std::thread::JoinHandle<()>> {
    Ok(std::thread::Builder::new().name("jsdoop-sweeper".into()).spawn(move || {
        while !stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(100));
            broker.sweep();
        }
    })?)
}

/// Bind one `SO_REUSEPORT` listener per shard on the same port (Linux,
/// `loop_shards > 1`). All-or-nothing: any failure drops the lot and the
/// caller falls back to distribute mode.
#[cfg(target_os = "linux")]
fn try_reuseport_group(
    addr: &str,
    nshards: usize,
) -> Option<(Vec<TcpListener>, std::net::SocketAddr)> {
    use std::net::ToSocketAddrs;
    let sa = addr.to_socket_addrs().ok()?.next()?;
    let first = shard::bind_reuseport(&sa).ok()?;
    // Re-resolve through the first bind so an ephemeral port 0 lands all
    // shards on the same concrete port.
    let local = first.local_addr().ok()?;
    let mut listeners = vec![first];
    for _ in 1..nshards {
        listeners.push(shard::bind_reuseport(&local).ok()?);
    }
    Some((listeners, local))
}

/// Decide how each shard comes by connections: per-shard `SO_REUSEPORT`
/// listeners when the platform cooperates, otherwise a single listener
/// on shard 0 distributing round-robin.
#[cfg(unix)]
fn plan_accept(
    addr: &str,
    nshards: usize,
) -> Result<(Vec<(Option<TcpListener>, AcceptMode)>, std::net::SocketAddr)> {
    #[cfg(target_os = "linux")]
    if nshards > 1 {
        if let Some((listeners, local)) = try_reuseport_group(addr, nshards) {
            let plan = listeners.into_iter().map(|l| (Some(l), AcceptMode::Own)).collect();
            return Ok((plan, local));
        }
    }
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let mode = if nshards > 1 { AcceptMode::Distribute } else { AcceptMode::Own };
    let mut plan = vec![(Some(listener), mode)];
    for _ in 1..nshards {
        plan.push((None, AcceptMode::Handoff));
    }
    Ok((plan, local))
}

/// Serve with explicit tuning (`server_workers` / `max_connections` /
/// `loop_shards` / `poller` from the config land here via `jsdoop serve`).
#[cfg(unix)]
pub fn serve_with(
    addr: &str,
    broker: Arc<dyn QueueService>,
    store: Arc<Store>,
    opts: ServerOptions,
) -> Result<ServerHandle> {
    let nshards = opts.loop_shards.clamp(1, obs::MAX_SHARDS);
    let (plan, local) = plan_accept(addr, nshards)?;
    obs::set_active_shards(nshards);
    let stop = Arc::new(AtomicBool::new(false));
    let conns_total = Arc::new(AtomicUsize::new(0));

    // One self-pipe (socketpair) per shard, waking its poller wait from
    // workers, wakers, and peer shards.
    let mut signals = Vec::with_capacity(nshards);
    let mut pipe_rxs = Vec::with_capacity(nshards);
    for _ in 0..nshards {
        let (pipe_rx, pipe_tx) = UnixStream::pair()?;
        pipe_rx.set_nonblocking(true)?;
        pipe_tx.set_nonblocking(true)?;
        signals.push(Arc::new(LoopSignal::new(pipe_tx)));
        pipe_rxs.push(pipe_rx);
    }

    let (work_tx, work_rx) = mpsc::channel::<Work>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let workers = opts.effective_workers();
    let mut threads = Vec::with_capacity(nshards + workers + 1);

    for (i, (listener, accept_mode)) in plan.into_iter().enumerate() {
        let poller = make_poller(opts.poller)
            .map_err(|e| anyhow::anyhow!("poller backend unavailable: {e}"))?;
        let sh = Shard::new(ShardSetup {
            index: i,
            nshards,
            listener,
            accept_mode,
            stop: stop.clone(),
            signal: signals[i].clone(),
            peers: signals.clone(),
            pipe_rx: pipe_rxs.remove(0),
            poller,
            work_tx: work_tx.clone(),
            broker: broker.clone(),
            store: store.clone(),
            opts: opts.clone(),
            conns_total: conns_total.clone(),
        });
        threads.push(
            std::thread::Builder::new()
                .name(format!("jsdoop-eventloop-{i}"))
                .spawn(move || sh.run())?,
        );
    }
    drop(work_tx); // the shards hold the only work senders now

    for i in 0..workers {
        let work_rx = work_rx.clone();
        let broker = broker.clone();
        let store = store.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("jsdoop-worker-{i}"))
                .spawn(move || worker_loop(&work_rx, broker.as_ref(), &store))?,
        );
    }
    threads.push(spawn_sweeper(broker.clone(), stop.clone())?);

    Ok(ServerHandle { addr: local, stop, signals, threads, broker, store })
}

/// Degraded fallback for targets without `poll(2)`: the previous
/// thread-per-connection loop. Same wire semantics; none of the scaling,
/// and connection threads are detached (not joined by shutdown).
#[cfg(not(unix))]
pub fn serve_with(
    addr: &str,
    broker: Arc<dyn QueueService>,
    store: Arc<Store>,
    opts: ServerOptions,
) -> Result<ServerHandle> {
    let _ = &opts;
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let sweeper = spawn_sweeper(broker.clone(), stop.clone())?;
    let accept = {
        let broker = broker.clone();
        let store = store.clone();
        let stop = stop.clone();
        std::thread::Builder::new().name("jsdoop-accept".into()).spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let broker = broker.clone();
                let store = store.clone();
                let stop = stop.clone();
                let _ = std::thread::Builder::new().name("jsdoop-conn".into()).spawn(move || {
                    let _ = blocking_conn(stream, local, broker.as_ref(), &store, &stop);
                });
            }
        })?
    };
    Ok(ServerHandle { addr: local, stop, threads: vec![accept, sweeper], broker, store })
}

#[cfg(not(unix))]
fn blocking_conn(
    mut stream: TcpStream,
    local: std::net::SocketAddr,
    broker: &dyn QueueService,
    store: &Store,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true)?;
    loop {
        let Ok((op_byte, body)) = read_frame(&mut stream) else {
            return Ok(()); // client disconnected
        };
        let op = match Op::from_u8(op_byte) {
            Ok(op) => op,
            Err(e) => {
                write_frame(&mut stream, ST_ERR, e.to_string().as_bytes())?;
                continue;
            }
        };
        if matches!(op, Op::Shutdown) {
            stop.store(true, Ordering::SeqCst);
            // The accept thread is parked in listener.incoming(); poke it
            // with a throwaway self-connection so it re-checks the flag.
            let _ = TcpStream::connect(poke_addr(local));
            write_frame(&mut stream, ST_OK, &[])?;
            return Ok(());
        }
        match execute_op(op, &body, broker, store) {
            Ok((st, resp)) => write_frame(&mut stream, st, &resp)?,
            Err(e) => write_frame(&mut stream, ST_ERR, e.to_string().as_bytes())?,
        }
    }
}

// ---------------------------------------------------------------------------
// Op execution (shared by the worker pool, the non-unix fallback, and the
// bench baseline)
// ---------------------------------------------------------------------------

/// How [`execute_op_with`] treats the timeout field of blocking ops.
#[cfg_attr(not(unix), allow(dead_code))]
enum TimeoutMode {
    /// Honor it in place, sleeping inside the broker/store — for
    /// thread-per-connection callers (non-unix fallback, bench baseline).
    Block,
    /// Replace it with zero: the event loop parks the connection instead
    /// of blocking a worker; retries arrive via wakers.
    Immediate,
}

/// Execute one request against `broker`/`store`, honoring blocking
/// timeouts in place; returns `(status, response body)`. Public so the
/// scaling bench can drive a thread-per-connection baseline over the very
/// same op implementations. `Op::Shutdown` only acknowledges — stopping
/// the server is the hosting loop's job.
pub fn execute_op(
    op: Op,
    body: &[u8],
    broker: &dyn QueueService,
    store: &Store,
) -> Result<(u8, Vec<u8>)> {
    execute_op_with(op, body, broker, store, TimeoutMode::Block)
}

fn execute_op_with(
    op: Op,
    body: &[u8],
    broker: &dyn QueueService,
    store: &Store,
    mode: TimeoutMode,
) -> Result<(u8, Vec<u8>)> {
    let mut r = BodyReader::new(body);
    let op_timeout = |t: Duration| match mode {
        TimeoutMode::Block => t,
        TimeoutMode::Immediate => Duration::ZERO,
    };
    Ok(match op {
        Op::Ping => (ST_OK, b"pong".to_vec()),
        Op::Shutdown => (ST_OK, Vec::new()),
        Op::Declare => {
            broker.declare(r.str()?)?;
            (ST_OK, Vec::new())
        }
        Op::Publish => {
            let q = r.str()?;
            broker.publish(q, r.rest())?;
            (ST_OK, Vec::new())
        }
        Op::PublishPri => {
            let q = r.str()?;
            let pri = r.u64()?;
            broker.publish_pri(q, r.rest(), pri)?;
            (ST_OK, Vec::new())
        }
        Op::Consume => {
            let q = r.str()?;
            let timeout = op_timeout(Duration::from_millis(r.u64()?));
            match broker.consume(q, timeout)? {
                Some(d) => {
                    let mut out = Vec::with_capacity(9 + d.payload.len());
                    out.extend_from_slice(&d.tag.to_le_bytes());
                    out.push(d.redelivered as u8);
                    out.extend_from_slice(&d.payload);
                    (ST_OK, out)
                }
                None => (ST_NONE, Vec::new()),
            }
        }
        Op::Ack => {
            let q = r.str()?;
            broker.ack(q, r.u64()?)?;
            (ST_OK, Vec::new())
        }
        Op::Nack => {
            let q = r.str()?;
            broker.nack(q, r.u64()?)?;
            (ST_OK, Vec::new())
        }
        Op::Len => {
            let n = broker.len(r.str()?)? as u64;
            (ST_OK, n.to_le_bytes().to_vec())
        }
        Op::Purge => {
            broker.purge(r.str()?)?;
            (ST_OK, Vec::new())
        }
        Op::Stats => {
            let s = broker.stats(r.str()?)?;
            let mut out = Vec::with_capacity(56);
            for v in [
                s.published,
                s.delivered,
                s.acked,
                s.nacked,
                s.redelivered,
                s.ready as u64,
                s.unacked as u64,
            ] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            (ST_OK, out)
        }
        Op::PublishMany => {
            let q = r.str()?;
            let n = r.u32()? as usize;
            // Each message costs at least its 4-byte length prefix, so a
            // count claiming more is corrupt — reject before allocating.
            // Division form: `n * 4` wraps usize on 32-bit targets.
            if n > body.len() / 4 {
                anyhow::bail!("batch count {n} exceeds body size");
            }
            let mut payloads = Vec::with_capacity(n);
            for _ in 0..n {
                payloads.push(r.bytes()?);
            }
            broker.publish_many(q, &payloads)?;
            (ST_OK, Vec::new())
        }
        Op::ConsumeMany => {
            let q = r.str()?;
            let max = r.u64()? as usize;
            let timeout = op_timeout(Duration::from_millis(r.u64()?));
            let mut batch = broker.consume_many(q, max, timeout)?;
            // A batch of large payloads can overflow MAX_FRAME. Erroring
            // after the pop would strand the deliveries in unacked until
            // the visibility timeout — instead send the prefix that fits
            // and NACK the rest straight back to their original slots
            // (lossless: they lead the very next consume).
            let mut body_len = 5; // status byte + count u32
            let mut fits = 0;
            while fits < batch.len() {
                let need = 13 + batch[fits].payload.len();
                if body_len + need > MAX_FRAME {
                    break;
                }
                body_len += need;
                fits += 1;
            }
            if fits == 0 && !batch.is_empty() {
                fits = 1; // single oversized message: fail like Op::Consume
            }
            if fits < batch.len() {
                let tags: Vec<u64> = batch[fits..].iter().map(|d| d.tag).collect();
                broker.nack_many(q, &tags)?;
                batch.truncate(fits);
            }
            if batch.is_empty() {
                (ST_NONE, Vec::new())
            } else {
                let size = 4 + batch.iter().map(|d| 13 + d.payload.len()).sum::<usize>();
                let mut out = Vec::with_capacity(size);
                put_u32(&mut out, batch.len() as u32);
                for d in &batch {
                    out.extend_from_slice(&d.tag.to_le_bytes());
                    out.push(d.redelivered as u8);
                    put_bytes(&mut out, &d.payload);
                }
                (ST_OK, out)
            }
        }
        Op::AckMany => {
            let q = r.str()?;
            let tags = read_tags(&mut r, body.len())?;
            broker.ack_many(q, &tags)?;
            (ST_OK, Vec::new())
        }
        Op::NackMany => {
            let q = r.str()?;
            let tags = read_tags(&mut r, body.len())?;
            broker.nack_many(q, &tags)?;
            (ST_OK, Vec::new())
        }
        Op::Put => {
            let k = r.str()?;
            store.put(k, r.rest())?;
            (ST_OK, Vec::new())
        }
        Op::Get => match store.get(r.str()?)? {
            Some(v) => (ST_OK, v),
            None => (ST_NONE, Vec::new()),
        },
        Op::Del => {
            let existed = store.del(r.str()?)?;
            (ST_OK, vec![existed as u8])
        }
        Op::PutVersioned => {
            let k = r.str()?;
            let ver = r.u64()?;
            store.put_versioned(k, ver, r.rest())?;
            (ST_OK, Vec::new())
        }
        Op::GetVersioned => match store.get_versioned(r.str()?)? {
            Some(v) => {
                let mut out = Vec::with_capacity(8 + v.bytes.len());
                out.extend_from_slice(&v.version.to_le_bytes());
                out.extend_from_slice(&v.bytes);
                (ST_OK, out)
            }
            None => (ST_NONE, Vec::new()),
        },
        Op::WaitVersion => {
            let k = r.str()?;
            let min = r.u64()?;
            let timeout = op_timeout(Duration::from_millis(r.u64()?));
            match store.wait_version(k, min, timeout)? {
                Some(v) => {
                    let mut out = Vec::with_capacity(8 + v.bytes.len());
                    out.extend_from_slice(&v.version.to_le_bytes());
                    out.extend_from_slice(&v.bytes);
                    (ST_OK, out)
                }
                None => (ST_NONE, Vec::new()),
            }
        }
        Op::Incr => {
            let v = store.incr(r.str()?)?;
            (ST_OK, v.to_le_bytes().to_vec())
        }
        Op::Metrics => {
            // Sampled gauges: values owned by other subsystems are read
            // at snapshot time instead of being maintained on their hot
            // paths (the snapshot is the rare path).
            obs::gauge_set(obs::Gauge::StoreWaiters, store.waiter_count() as i64);
            let snap = obs::snapshot(broker.metrics_queues());
            (ST_OK, obs::encode(&snap))
        }
        // --- replication (queue/durability/replication) --------------------
        // All three answer from the WAL-backed broker behind this service;
        // a plain in-memory broker (or a replica) has no log to ship.
        Op::ReplHandshake => {
            let db = repl_source(broker)?;
            let status = db.repl_status()?;
            (ST_OK, status_body(&status, 0))
        }
        Op::ReplSnapshot => {
            let db = repl_source(broker)?;
            let (gen, bytes) = db.repl_snapshot()?;
            if 9 + bytes.len() > MAX_FRAME {
                // v0 limitation: a baseline must fit one frame. Chunked
                // snapshot shipping rides the same ops later if needed.
                anyhow::bail!(
                    "snapshot of {} bytes exceeds the replication frame cap",
                    bytes.len()
                );
            }
            let mut out = Vec::with_capacity(8 + bytes.len());
            out.extend_from_slice(&gen.to_le_bytes());
            out.extend_from_slice(&bytes);
            (ST_OK, out)
        }
        Op::ReplPull => {
            let db = repl_source(broker)?;
            let gen = r.u64()?;
            let from = r.u64()?;
            let max = r.u32()? as usize;
            let (status, chunk) = db.repl_read(gen, from, max)?;
            let mut out = status_body(&status, chunk.len());
            out.extend_from_slice(&chunk);
            (ST_OK, out)
        }
        // --- job (tenant) namespace ops (queue/job.rs) ----------------------
        Op::DeclareJob => {
            let jobid = r.str()?;
            broker.declare_job(jobid, r.str()?)?;
            (ST_OK, Vec::new())
        }
        Op::PublishJob => {
            let jobid = r.str()?;
            let q = r.str()?;
            let pri = r.u64()?;
            match broker.publish_job(jobid, q, r.rest(), pri) {
                Ok(()) => (ST_OK, Vec::new()),
                Err(e) => quota_status(e)?,
            }
        }
        Op::PublishManyJob => {
            let jobid = r.str()?;
            let q = r.str()?;
            let n = r.u32()? as usize;
            // Same hostile-count audit as Op::PublishMany (division form:
            // `n * 4` wraps usize on 32-bit targets).
            if n > body.len() / 4 {
                anyhow::bail!("batch count {n} exceeds body size");
            }
            let mut payloads = Vec::with_capacity(n);
            for _ in 0..n {
                payloads.push(r.bytes()?);
            }
            match broker.publish_many_job(jobid, q, &payloads) {
                Ok(()) => (ST_OK, Vec::new()),
                Err(e) => quota_status(e)?,
            }
        }
        Op::ConsumeFair => {
            let base = r.str()?;
            // Never parks: the deficit-round-robin pull has no single
            // queue to register a waiter on, so the event loop answers
            // from what is ready right now and remote agents poll.
            let timeout = op_timeout(Duration::from_millis(r.u64()?));
            match broker.consume_fair(base, timeout)? {
                Some((jobid, d)) => {
                    let mut out = Vec::with_capacity(11 + jobid.len() + d.payload.len());
                    put_str(&mut out, &jobid);
                    out.extend_from_slice(&d.tag.to_le_bytes());
                    out.push(d.redelivered as u8);
                    out.extend_from_slice(&d.payload);
                    (ST_OK, out)
                }
                None => (ST_NONE, Vec::new()),
            }
        }
        Op::ListJobs => {
            let rows = broker.list_jobs()?;
            let mut out = Vec::new();
            put_u32(&mut out, rows.len() as u32);
            for j in &rows {
                put_str(&mut out, &j.job);
                for v in [
                    j.queues,
                    j.ready_msgs,
                    j.ready_bytes,
                    j.quota.max_ready_msgs,
                    j.quota.max_ready_bytes,
                ] {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            (ST_OK, out)
        }
        Op::SetJobQuota => {
            let jobid = r.str()?;
            let quota = JobQuota { max_ready_msgs: r.u64()?, max_ready_bytes: r.u64()? };
            broker.set_job_quota(jobid, quota)?;
            (ST_OK, Vec::new())
        }
        Op::RemoveJob => {
            let removed = broker.remove_job(r.str()?)?;
            (ST_OK, removed.to_le_bytes().to_vec())
        }
    })
}

/// Map an over-quota publish to the in-band [`ST_QUOTA`] status; every
/// other error propagates (and poisons nothing — the dispatch loop
/// answers `ST_ERR` with the message, same as always). The body carries
/// only the detail: the requester named the job in its own request, and
/// shipping the bare detail lets `RemoteQueue` reconstruct the typed
/// [`QuotaExceeded`] exactly as the broker raised it.
fn quota_status(e: anyhow::Error) -> Result<(u8, Vec<u8>)> {
    match e.downcast_ref::<QuotaExceeded>() {
        Some(q) => Ok((ST_QUOTA, q.detail.clone().into_bytes())),
        None => Err(e),
    }
}

fn repl_source(broker: &dyn QueueService) -> Result<&crate::queue::durability::DurableBroker> {
    broker.replication().ok_or_else(|| {
        anyhow::anyhow!("replication unavailable: this server is not backed by a durable (WAL) broker")
    })
}

/// `[gen u64][durable_bytes u64][appended_bytes u64]` — the watermark
/// prefix of ReplHandshake/ReplPull responses.
fn status_body(status: &crate::queue::durability::ReplStatus, chunk_len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(24 + chunk_len);
    out.extend_from_slice(&status.gen.to_le_bytes());
    out.extend_from_slice(&status.durable_bytes.to_le_bytes());
    out.extend_from_slice(&status.appended_bytes.to_le_bytes());
    out
}

/// Parse a `[count u32][tag u64]*` tail (AckMany/NackMany bodies), with a
/// sanity bound so a corrupt count cannot trigger a huge allocation.
fn read_tags(r: &mut BodyReader<'_>, body_len: usize) -> Result<Vec<u64>> {
    let n = r.u32()? as usize;
    // Division form: `n * 8` wraps usize on 32-bit targets.
    if n > body_len / 8 {
        anyhow::bail!("tag count {n} exceeds body size");
    }
    let mut tags = Vec::with_capacity(n);
    for _ in 0..n {
        tags.push(r.u64()?);
    }
    Ok(tags)
}

/// Client-side helper shared with `client.rs`: send one request, read the
/// response frame.
pub(crate) fn roundtrip(
    stream: &mut TcpStream,
    op: Op,
    body: &[u8],
) -> Result<(u8, Vec<u8>)> {
    write_frame(stream, op as u8, body)?;
    read_frame(stream)
}

/// Build a body that starts with a name string.
pub(crate) fn body_with_name(name: &str, extra: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(2 + name.len() + extra.len());
    put_str(&mut out, name);
    out.extend_from_slice(extra);
    out
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use crate::queue::broker::Broker;

    #[test]
    fn execute_op_matches_wire_shapes() {
        let broker = Broker::new(Duration::from_secs(5));
        let store = Store::new();
        let (st, body) = execute_op(Op::Ping, &[], &broker, &store).unwrap();
        assert_eq!((st, body.as_slice()), (ST_OK, b"pong".as_slice()));
        let (st, _) =
            execute_op(Op::Declare, &body_with_name("q", &[]), &broker, &store).unwrap();
        assert_eq!(st, ST_OK);
        // Immediate mode turns a long blocking consume into a fast try.
        let mut c = body_with_name("q", &[]);
        c.extend_from_slice(&10_000u64.to_le_bytes());
        let t0 = std::time::Instant::now();
        let (st, _) =
            execute_op_with(Op::Consume, &c, &broker, &store, TimeoutMode::Immediate).unwrap();
        assert_eq!(st, ST_NONE);
        assert!(t0.elapsed() < Duration::from_secs(2));
    }
}
