//! `poll(2)` readiness backend: the portable fallback (default off
//! Linux). The kernel has no persistent interest set for `poll`, so this
//! backend keeps the fd table in userspace and rebuilds the `pollfd`
//! array on every wait — O(open connections) per round, which is exactly
//! the cost curve the epoll backend exists to avoid. Below ~10k
//! connections the difference is noise; the backend stays because it
//! runs on every unix and keeps the parity test matrix honest.

use std::collections::HashMap;
use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

use super::poller::{Event, Interest, Poller};

/// Minimal `poll(2)` FFI. The dependency budget (anyhow + once_cell only)
/// rules out `libc`/`mio`, so the one syscall this backend needs is
/// declared by hand. Constants match every mainstream unix.
mod sys {
    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    #[repr(C)]
    #[derive(Clone, Copy, Debug)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    // nfds_t is unsigned long on linux, unsigned int on the BSDs/macOS.
    #[cfg(target_os = "linux")]
    type Nfds = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type Nfds = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
    }

    /// Wait for readiness on `fds` (or `timeout`). EINTR reports as zero
    /// events: the caller's loop re-runs housekeeping and polls again.
    pub fn wait(fds: &mut [PollFd], timeout: Duration) -> io::Result<usize> {
        let ms = timeout.as_millis().min(i32::MAX as u128) as c_int;
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, ms) };
        if rc < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(err);
        }
        Ok(rc as usize)
    }
}

pub(crate) struct PollPoller {
    /// fd → (token, interest). Empty-interest entries stay in the map
    /// but are skipped when the `pollfd` array is built, so they report
    /// nothing — matching the trait contract (and epoll's CTL_DEL).
    registered: HashMap<RawFd, (usize, Interest)>,
    /// Scratch reused across waits (`tokens` runs parallel to `fds`).
    fds: Vec<sys::PollFd>,
    tokens: Vec<usize>,
}

impl PollPoller {
    pub(crate) fn new() -> Self {
        PollPoller { registered: HashMap::new(), fds: Vec::new(), tokens: Vec::new() }
    }
}

impl Poller for PollPoller {
    fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.registered.insert(fd, (token, interest));
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.registered.insert(fd, (token, interest));
        Ok(())
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.registered.remove(&fd);
        Ok(())
    }

    fn wait(&mut self, timeout: Duration, out: &mut Vec<Event>) -> io::Result<usize> {
        self.fds.clear();
        self.tokens.clear();
        for (&fd, &(token, interest)) in &self.registered {
            if interest.is_empty() {
                continue;
            }
            let mut events = 0i16;
            if interest.readable {
                events |= sys::POLLIN;
            }
            if interest.writable {
                events |= sys::POLLOUT;
            }
            self.fds.push(sys::PollFd { fd, events, revents: 0 });
            self.tokens.push(token);
        }
        let n = sys::wait(&mut self.fds, timeout)?;
        if n == 0 {
            return Ok(0);
        }
        let mut appended = 0;
        for (pfd, &token) in self.fds.iter().zip(&self.tokens) {
            let re = pfd.revents;
            if re == 0 {
                continue;
            }
            out.push(Event {
                token,
                readable: re & sys::POLLIN != 0,
                writable: re & sys::POLLOUT != 0,
                error: re & (sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0,
            });
            appended += 1;
        }
        Ok(appended)
    }

    fn name(&self) -> &'static str {
        "poll"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn reports_readability_and_respects_empty_interest() {
        let (rx, mut tx) = UnixStream::pair().unwrap();
        let mut p = PollPoller::new();
        p.register(rx.as_raw_fd(), 7, Interest::READABLE).unwrap();
        tx.write_all(&[1]).unwrap();
        let mut out = Vec::new();
        let n = p.wait(Duration::from_millis(500), &mut out).unwrap();
        assert_eq!(n, 1);
        assert_eq!(out[0].token, 7);
        assert!(out[0].readable);

        // Empty interest: the byte is still unread, but nothing reports.
        p.modify(rx.as_raw_fd(), 7, Interest::NONE).unwrap();
        out.clear();
        let n = p.wait(Duration::from_millis(10), &mut out).unwrap();
        assert_eq!(n, 0);

        p.deregister(rx.as_raw_fd()).unwrap();
        out.clear();
        assert_eq!(p.wait(Duration::from_millis(10), &mut out).unwrap(), 0);
    }
}
