//! Pluggable readiness backends for the event loop.
//!
//! The loop's contract with a backend is a token→interest map:
//!
//! * [`Poller::register`] / [`Poller::modify`] — declare what a file
//!   descriptor should be watched for ([`Interest`]), tagged with a
//!   caller-chosen `token` that comes back verbatim in events. An EMPTY
//!   interest means "registered but not watched at all": no event —
//!   not even an error event — may be reported for it. (This is how the
//!   loop expresses "a frame from this connection is mid-execute in the
//!   worker pool"; the epoll backend maps it to `EPOLL_CTL_DEL` because
//!   epoll cannot mask ERR/HUP.)
//! * [`Poller::deregister`] — forget the fd. MUST be called before the
//!   fd is closed: the `poll(2)` backend keeps its own fd table and
//!   would otherwise poll a dead descriptor forever (`POLLNVAL` spin).
//! * [`Poller::wait`] — block until readiness or timeout, appending
//!   [`Event`]s. Signal interruption (EINTR) reports as zero events so
//!   the caller re-runs housekeeping and waits again.
//!
//! Both implementations are level-triggered: an event the loop does not
//! consume is simply reported again next round, so a partial read or a
//! skipped accept can never strand a connection. Edge-triggered modes
//! were deliberately rejected — they demand drain-until-EAGAIN on every
//! event, which conflicts with the loop's per-round read budget
//! (fairness) and buys nothing at this op rate.

#[cfg(unix)]
use std::io;
#[cfg(unix)]
use std::os::unix::io::RawFd;
#[cfg(unix)]
use std::time::Duration;

/// Which readiness backend the event loop uses.
///
/// `Auto` resolves to `epoll` on Linux (falling back to `poll` if the
/// epoll instance cannot be created) and to `poll` everywhere else.
/// `poll(2)` rebuilds an O(open) fd set every round and the kernel scans
/// all of it; `epoll` pays one syscall per interest *change* and its
/// wait cost is O(ready) — the difference is what pushes the server past
/// ~50k mostly-idle volunteers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollerKind {
    Auto,
    Poll,
    Epoll,
}

impl std::str::FromStr for PollerKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "auto" => Ok(PollerKind::Auto),
            "poll" => Ok(PollerKind::Poll),
            "epoll" => Ok(PollerKind::Epoll),
            other => anyhow::bail!("unknown poller '{other}' (expected auto, poll, or epoll)"),
        }
    }
}

impl std::fmt::Display for PollerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PollerKind::Auto => "auto",
            PollerKind::Poll => "poll",
            PollerKind::Epoll => "epoll",
        })
    }
}

/// What an fd is watched for. Empty interest = enrolled but silent.
#[cfg(unix)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Interest {
    pub readable: bool,
    pub writable: bool,
}

#[cfg(unix)]
impl Interest {
    pub(crate) const NONE: Interest = Interest { readable: false, writable: false };
    pub(crate) const READABLE: Interest = Interest { readable: true, writable: false };
    pub(crate) const WRITABLE: Interest = Interest { readable: false, writable: true };

    pub(crate) fn is_empty(self) -> bool {
        !self.readable && !self.writable
    }
}

/// One readiness report. `error` collapses the backend's ERR/HUP/NVAL
/// bits: the loop resolves what actually happened through `read()`/
/// `write()`, which report the concrete error.
#[cfg(unix)]
#[derive(Debug, Clone, Copy)]
pub(crate) struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
    pub error: bool,
}

/// Token of the shard's self-pipe read end.
#[cfg(unix)]
pub(crate) const TOKEN_PIPE: usize = usize::MAX;
/// Token of the shard's listener (absent while backed off / at the cap).
#[cfg(unix)]
pub(crate) const TOKEN_LISTENER: usize = usize::MAX - 1;

/// A readiness backend. Object-safe so a shard can hold `Box<dyn Poller>`
/// chosen at serve time from config.
#[cfg(unix)]
pub(crate) trait Poller: Send {
    fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()>;
    fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()>;
    /// Forget `fd`. Must precede closing the descriptor.
    fn deregister(&mut self, fd: RawFd) -> io::Result<()>;
    /// Wait for readiness or `timeout`, appending to `out` (not cleared
    /// here). Returns the number of events appended; EINTR is `Ok(0)`.
    fn wait(&mut self, timeout: Duration, out: &mut Vec<Event>) -> io::Result<usize>;
    fn name(&self) -> &'static str;
}

/// Build the backend `kind` asks for. `Auto` never fails (it falls back
/// to `poll`); an explicit `Epoll` reports why it cannot be had.
#[cfg(unix)]
pub(crate) fn make_poller(kind: PollerKind) -> io::Result<Box<dyn Poller>> {
    match kind {
        PollerKind::Poll => Ok(Box::new(super::poll_backend::PollPoller::new())),
        #[cfg(target_os = "linux")]
        PollerKind::Epoll => Ok(Box::new(super::epoll_backend::EpollPoller::new()?)),
        #[cfg(not(target_os = "linux"))]
        PollerKind::Epoll => Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "the epoll backend is linux-only; use poller=auto or poller=poll",
        )),
        #[cfg(target_os = "linux")]
        PollerKind::Auto => Ok(match super::epoll_backend::EpollPoller::new() {
            Ok(p) => Box::new(p),
            Err(_) => Box::new(super::poll_backend::PollPoller::new()),
        }),
        #[cfg(not(target_os = "linux"))]
        PollerKind::Auto => Ok(Box::new(super::poll_backend::PollPoller::new())),
    }
}

#[cfg(test)]
mod tests {
    use super::PollerKind;

    #[test]
    fn poller_kind_parses_and_rejects() {
        assert_eq!("auto".parse::<PollerKind>().unwrap(), PollerKind::Auto);
        assert_eq!("poll".parse::<PollerKind>().unwrap(), PollerKind::Poll);
        assert_eq!("epoll".parse::<PollerKind>().unwrap(), PollerKind::Epoll);
        assert!("kqueue".parse::<PollerKind>().is_err());
        assert_eq!(PollerKind::Epoll.to_string(), "epoll");
    }

    #[cfg(unix)]
    #[test]
    fn auto_always_yields_a_backend() {
        let p = super::make_poller(PollerKind::Auto).unwrap();
        if cfg!(target_os = "linux") {
            assert_eq!(p.name(), "epoll");
        } else {
            assert_eq!(p.name(), "poll");
        }
    }
}
