//! `epoll` readiness backend (Linux): the kernel owns the interest set,
//! so a wait costs O(ready events) instead of `poll(2)`'s O(open
//! connections) — the difference between a loop that saturates near 10k
//! mostly-idle volunteers and one that coasts past 50k.
//!
//! Level-triggered on purpose: the shard loop consumes at most one frame
//! per readiness report (fairness budget) and relies on unconsumed
//! readiness being re-reported. Edge-triggered epoll would force
//! drain-until-EAGAIN semantics the loop doesn't want.
//!
//! One contract wrinkle: epoll always reports `EPOLLERR`/`EPOLLHUP` for
//! enrolled fds — they cannot be masked out of `events`. The [`Poller`]
//! trait promises that an EMPTY interest reports *nothing* (the loop
//! parks connections mid-execute that way), so empty interest maps to
//! `EPOLL_CTL_DEL` and the first non-empty interest re-`ADD`s; the
//! `enrolled` set tracks which state each fd is in.
//!
//! FFI is hand-rolled under the same dependency budget as the `poll`
//! backend (anyhow + once_cell only — no `libc`/`mio`). `epoll_event` is
//! packed on x86-64, matching the kernel ABI.

use std::collections::HashSet;
use std::io;
use std::os::raw::c_int;
use std::os::unix::io::RawFd;
use std::time::Duration;

use super::poller::{Event, Interest, Poller};

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;

const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;

// The kernel's struct epoll_event is packed on x86-64 (a 12-byte struct
// with an 8-byte payload at offset 4); other architectures use natural
// alignment.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn close(fd: c_int) -> c_int;
}

pub(crate) struct EpollPoller {
    epfd: RawFd,
    /// fds currently `ADD`ed in the kernel set (empty-interest fds are
    /// deliberately absent — see the module doc).
    enrolled: HashSet<RawFd>,
    /// Event buffer reused across waits; doubled when a wait fills it
    /// (more ready fds exist — level-triggered epoll re-reports them,
    /// but a bigger buffer gets them all in one syscall next time).
    buf: Vec<EpollEvent>,
}

impl EpollPoller {
    pub(crate) fn new() -> io::Result<Self> {
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(EpollPoller {
            epfd,
            enrolled: HashSet::new(),
            buf: vec![EpollEvent { events: 0, data: 0 }; 1024],
        })
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = 0;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    fn ctl(&self, op: c_int, fd: RawFd, interest: Interest, token: usize) -> io::Result<()> {
        let mut ev = EpollEvent { events: Self::mask(interest), data: token as u64 };
        let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Reconcile the kernel set with the desired interest; register and
    /// modify are the same operation under this state machine.
    fn apply(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        match (self.enrolled.contains(&fd), !interest.is_empty()) {
            (false, true) => {
                self.ctl(EPOLL_CTL_ADD, fd, interest, token)?;
                self.enrolled.insert(fd);
                Ok(())
            }
            (true, true) => self.ctl(EPOLL_CTL_MOD, fd, interest, token),
            (true, false) => {
                self.ctl(EPOLL_CTL_DEL, fd, interest, token)?;
                self.enrolled.remove(&fd);
                Ok(())
            }
            (false, false) => Ok(()),
        }
    }
}

impl Poller for EpollPoller {
    fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.apply(fd, token, interest)
    }

    fn modify(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
        self.apply(fd, token, interest)
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        if self.enrolled.remove(&fd) {
            // The fd may already be closed (kernel auto-removed it);
            // a failed DEL is not actionable.
            let _ = self.ctl(EPOLL_CTL_DEL, fd, Interest::NONE, 0);
        }
        Ok(())
    }

    fn wait(&mut self, timeout: Duration, out: &mut Vec<Event>) -> io::Result<usize> {
        let ms = timeout.as_millis().min(i32::MAX as u128) as c_int;
        let rc =
            unsafe { epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, ms) };
        if rc < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(err);
        }
        let n = rc as usize;
        for ev in &self.buf[..n] {
            let events = ev.events;
            out.push(Event {
                token: ev.data as usize,
                readable: events & EPOLLIN != 0,
                writable: events & EPOLLOUT != 0,
                error: events & (EPOLLERR | EPOLLHUP) != 0,
            });
        }
        if n == self.buf.len() {
            let grow = self.buf.len();
            self.buf.resize(grow * 2, EpollEvent { events: 0, data: 0 });
        }
        Ok(n)
    }

    fn name(&self) -> &'static str {
        "epoll"
    }
}

impl Drop for EpollPoller {
    fn drop(&mut self) {
        unsafe {
            close(self.epfd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn reports_readability_and_respects_empty_interest() {
        let (rx, mut tx) = UnixStream::pair().unwrap();
        let mut p = EpollPoller::new().unwrap();
        p.register(rx.as_raw_fd(), 42, Interest::READABLE).unwrap();
        tx.write_all(&[1]).unwrap();
        let mut out = Vec::new();
        let n = p.wait(Duration::from_millis(500), &mut out).unwrap();
        assert_eq!(n, 1);
        assert_eq!(out[0].token, 42);
        assert!(out[0].readable);

        // Empty interest maps to CTL_DEL: the unread byte (and even a
        // peer hangup) must report nothing.
        p.modify(rx.as_raw_fd(), 42, Interest::NONE).unwrap();
        drop(tx);
        out.clear();
        assert_eq!(p.wait(Duration::from_millis(10), &mut out).unwrap(), 0);

        // Re-adding after an empty phase works (ADD, not MOD).
        p.modify(rx.as_raw_fd(), 42, Interest::READABLE).unwrap();
        out.clear();
        let n = p.wait(Duration::from_millis(500), &mut out).unwrap();
        assert_eq!(n, 1);
        assert!(out[0].readable);

        p.deregister(rx.as_raw_fd()).unwrap();
        out.clear();
        assert_eq!(p.wait(Duration::from_millis(10), &mut out).unwrap(), 0);
    }
}
