//! One event-loop shard: owns a slice of the connections, their timer
//! heaps, and their waker registrations, multiplexing readiness through
//! a pluggable [`Poller`] backend. `--loop_shards=N` runs N of these on
//! their own threads; a single global worker pool executes decoded ops
//! for all of them, and each `Work` item carries the owning shard's
//! completion channel + wake signal so verdicts route home.
//!
//! Accept strategies ([`AcceptMode`]):
//! * `Own` — this shard accepts from its own listener and keeps every
//!   connection (the single-shard case, and the per-shard `SO_REUSEPORT`
//!   listeners on Linux where the kernel balances accepts).
//! * `Distribute` — this shard accepts from the single listener and
//!   round-robins accepted sockets across all shards via their
//!   [`LoopSignal`] handoff queues (the portable multi-shard fallback).
//! * `Handoff` — this shard never accepts; connections arrive only
//!   through its handoff queue.
//!
//! The loop structure (frame assembly, park/wake, backpressure,
//! drain-on-shutdown, idle reaping) is the PR 6/7 event loop verbatim;
//! only the readiness layer changed. Two bookkeeping deltas:
//! connection ids stride by the shard count so waiter registrations
//! (keyed by id) never collide across shards, and the lazily-invalidated
//! timer heaps now compact themselves once stale entries outnumber live
//! ones (see [`TimerHeap`]).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::io::{self, Read, Write};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::data::Store;
use crate::obs;
use crate::queue::wire::{BodyReader, FrameAssembler, Op, MAX_FRAME, ST_ERR, ST_NONE, ST_OK};
use crate::queue::{QueueService, ReadyWaker};

use super::poller::{Event, Interest, Poller, TOKEN_LISTENER, TOKEN_PIPE};
use super::{execute_op_with, ServerOptions, TimeoutMode};

/// Per-connection read budget per poll round, so one firehose connection
/// cannot starve the rest of the loop.
const READ_BUDGET: usize = 1 << 20;

/// Listener backoff after accept errors (EMFILE and friends): without it
/// a level-triggered listener spins the loop hot.
const ACCEPT_BACKOFF: Duration = Duration::from_millis(100);

/// Upper bound on a poll sleep, so a stop request is noticed even if the
/// wake-pipe byte were ever lost.
const IDLE_POLL: Duration = Duration::from_millis(500);

/// Cap on a blocking op's park. Protocol timeouts are client-controlled
/// u64 millis; uncapped they overflow `Instant` arithmetic.
const MAX_BLOCK: Duration = Duration::from_secs(24 * 60 * 60);

/// Shared wake channel into a shard: connection ids whose readiness
/// changed, sockets handed off by the accepting shard, plus a self-pipe
/// byte that interrupts the poller wait.
pub(super) struct LoopSignal {
    woken: Mutex<Vec<u64>>,
    handoff: Mutex<Vec<TcpStream>>,
    pipe_tx: UnixStream,
}

impl LoopSignal {
    pub(super) fn new(pipe_tx: UnixStream) -> Self {
        LoopSignal { woken: Mutex::new(Vec::new()), handoff: Mutex::new(Vec::new()), pipe_tx }
    }

    /// Interrupt the poll sleep. A full pipe already guarantees a pending
    /// wakeup, so the write result is deliberately ignored.
    pub(super) fn notify(&self) {
        let _ = (&self.pipe_tx).write(&[1]);
    }

    fn wake_conn(&self, id: u64) {
        self.woken.lock().unwrap().push(id);
        self.notify();
    }

    fn drain_woken(&self) -> Vec<u64> {
        std::mem::take(&mut *self.woken.lock().unwrap())
    }

    fn hand_off(&self, stream: TcpStream) {
        self.handoff.lock().unwrap().push(stream);
        self.notify();
    }

    fn drain_handoff(&self) -> Vec<TcpStream> {
        std::mem::take(&mut *self.handoff.lock().unwrap())
    }
}

/// The token a parked connection leaves with the broker/store: waking it
/// re-dispatches the parked op on the owning shard's loop.
struct ConnWaker {
    conn: u64,
    signal: Arc<LoopSignal>,
}

impl ReadyWaker for ConnWaker {
    fn wake(&self) {
        self.signal.wake_conn(self.conn);
    }
}

pub(super) struct Work {
    conn: u64,
    op: Op,
    body: Vec<u8>,
    /// Deadline of a blocking op. `None` on the first attempt (the worker
    /// derives it from the body's timeout field); carried through
    /// park/retry cycles so a retry never extends the client's timeout.
    deadline: Option<Instant>,
    waker: Arc<ConnWaker>,
    /// When this item entered the work channel — the worker's pickup
    /// delta is the `server.op_queue_wait_ns` histogram (pool saturation).
    enqueued: Instant,
    /// Completion channel of the shard that owns `conn` (the worker pool
    /// is global; verdicts must route back to the owning loop).
    done: mpsc::Sender<Done>,
}

enum Verdict {
    /// A complete response frame, ready to write.
    Respond(Vec<u8>),
    /// The op would block: park the connection until waker or deadline.
    Park { op: Op, body: Vec<u8>, deadline: Instant, site: WaitSite },
}

struct Done {
    conn: u64,
    verdict: Verdict,
}

/// What a parked op waits on (and where to cancel its registration).
#[derive(Debug, Clone)]
enum WaitSite {
    Queue(String),
    Version,
}

enum Phase {
    /// Assembling the next request frame.
    Reading,
    /// A frame is in the worker pool; the socket is not read meanwhile.
    Executing,
    /// A blocking op came up empty; waiting for a waker or the deadline.
    Parked(ParkedOp),
}

struct ParkedOp {
    op: Op,
    body: Vec<u8>,
    deadline: Instant,
    site: WaitSite,
}

struct Conn {
    stream: TcpStream,
    /// Peer IP at accept time — the key released from the per-IP
    /// accounting when this connection closes.
    peer_ip: Option<IpAddr>,
    asm: FrameAssembler,
    phase: Phase,
    out: Vec<u8>,
    out_pos: usize,
    /// A waker fired while the op was still executing: re-dispatch instead
    /// of parking when the Park verdict lands.
    wake_pending: bool,
    close_after_write: bool,
    waker: Arc<ConnWaker>,
    /// Last observed frame activity (readiness, dispatch, or response
    /// flush) — the idle-reaper's clock.
    last_activity: Instant,
    /// What the poller currently watches this socket for; reconciled
    /// against [`desired_interest`] before every wait.
    interest: Interest,
}

impl Conn {
    fn has_output(&self) -> bool {
        self.out_pos < self.out.len()
    }

    fn queue_response(&mut self, frame: Vec<u8>) {
        self.out = frame;
        self.out_pos = 0;
    }

    /// Push buffered output until the socket blocks. `false` = fatal.
    fn flush_output(&mut self) -> bool {
        while self.has_output() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => return false,
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    // Slow reader: the response waits for writability.
                    obs::inc(obs::Counter::ServerBackpressureStalls);
                    return true;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        self.out.clear();
        self.out_pos = 0;
        true
    }
}

/// What the poller should watch a connection for, derived from its
/// state. Parked consumers stay readable so a dead peer is caught (and
/// its waiter registration cancelled) immediately; executing connections
/// are watched for NOTHING — the protocol is synchronous, and the empty
/// interest keeps even error events quiet until the verdict lands.
fn desired_interest(c: &Conn, draining: bool) -> Interest {
    if c.has_output() {
        Interest::WRITABLE
    } else if matches!(c.phase, Phase::Reading) && !draining {
        Interest::READABLE
    } else if matches!(c.phase, Phase::Parked(_)) {
        Interest::READABLE
    } else {
        Interest::NONE
    }
}

enum Next {
    Keep,
    Close,
    Dispatch(Op, Vec<u8>),
    Shutdown,
}

/// A lazily-invalidated min-heap of `(due, conn id)` timers with bounded
/// garbage. Owners call [`TimerHeap::note_stale`] when a live entry stops
/// mapping to a real wait (a consumer woken before its deadline, a
/// closed connection); once known-stale entries outnumber live ones the
/// heap is rebuilt against a ground-truth predicate. Without this, a
/// connection that repeatedly parks and wakes before its deadline grows
/// the heap without bound (one dead entry per cycle) — the compaction
/// caps it at ~2x the live count. `stale` is an estimate and may
/// overshoot (e.g. a reaped connection whose entry was already popped);
/// that only makes compaction run early, never wrong, because the
/// rebuild keeps exactly what the predicate vouches for.
pub(super) struct TimerHeap {
    heap: BinaryHeap<Reverse<(Instant, u64)>>,
    stale: usize,
}

impl TimerHeap {
    fn new() -> Self {
        TimerHeap { heap: BinaryHeap::new(), stale: 0 }
    }

    fn arm(&mut self, due: Instant, id: u64) {
        self.heap.push(Reverse((due, id)));
    }

    fn peek(&self) -> Option<(Instant, u64)> {
        self.heap.peek().map(|&Reverse(e)| e)
    }

    fn pop(&mut self) {
        self.heap.pop();
    }

    /// An entry still in the heap went stale (resume-before-deadline,
    /// connection closed).
    fn note_stale(&mut self) {
        self.stale = (self.stale + 1).min(self.heap.len());
    }

    /// A popped entry turned out stale: it left the heap, so it no
    /// longer counts toward the compaction trigger.
    fn note_popped_stale(&mut self) {
        self.stale = self.stale.saturating_sub(1);
    }

    #[cfg_attr(not(test), allow(dead_code))]
    fn len(&self) -> usize {
        self.heap.len()
    }

    /// Rebuild once stale entries exceed half the heap (skipping tiny
    /// heaps where the O(n) rebuild would churn for nothing). `live`
    /// is the ground truth: keep exactly the entries it vouches for.
    fn maybe_compact(&mut self, live: impl Fn(u64, Instant) -> bool) {
        if self.heap.len() < 8 || self.stale <= self.heap.len() / 2 {
            return;
        }
        let old = std::mem::take(&mut self.heap);
        self.heap = old.into_iter().filter(|&Reverse((t, id))| live(id, t)).collect();
        self.stale = 0;
    }
}

/// How this shard comes by new connections; see the module doc.
pub(super) enum AcceptMode {
    Own,
    Distribute,
    Handoff,
}

/// Everything a shard is built from (a struct rather than a parameter
/// list so `serve_with` reads as configuration).
pub(super) struct ShardSetup {
    pub index: usize,
    pub nshards: usize,
    pub listener: Option<TcpListener>,
    pub accept_mode: AcceptMode,
    pub stop: Arc<AtomicBool>,
    pub signal: Arc<LoopSignal>,
    /// Every shard's signal (own included), indexed by shard — the
    /// distribute path and stop broadcasts fan out through these.
    pub peers: Vec<Arc<LoopSignal>>,
    pub pipe_rx: UnixStream,
    pub poller: Box<dyn Poller>,
    pub work_tx: mpsc::Sender<Work>,
    pub broker: Arc<dyn QueueService>,
    pub store: Arc<Store>,
    pub opts: ServerOptions,
    /// Live connections across ALL shards — `max_connections` stays a
    /// global cap under sharding.
    pub conns_total: Arc<AtomicUsize>,
}

pub(super) struct Shard {
    index: usize,
    nshards: usize,
    /// `None` once draining: dropping the listener closes the port
    /// immediately, which remote-Shutdown semantics require.
    listener: Option<TcpListener>,
    listener_registered: bool,
    accept_mode: AcceptMode,
    /// Round-robin cursor for `AcceptMode::Distribute`.
    rr: usize,
    stop: Arc<AtomicBool>,
    signal: Arc<LoopSignal>,
    peers: Vec<Arc<LoopSignal>>,
    pipe_rx: UnixStream,
    poller: Box<dyn Poller>,
    work_tx: mpsc::Sender<Work>,
    done_tx: mpsc::Sender<Done>,
    done_rx: mpsc::Receiver<Done>,
    broker: Arc<dyn QueueService>,
    store: Arc<Store>,
    opts: ServerOptions,
    conns: HashMap<u64, Conn>,
    conns_total: Arc<AtomicUsize>,
    /// Connection ids stride by `nshards` from `index`, so ids — which
    /// key waiter registrations with the broker/store — never collide
    /// across shards.
    next_id: u64,
    id_stride: u64,
    /// Park deadlines (lazily invalidated, self-compacting).
    timers: TimerHeap,
    /// Idle-reap checkpoints (same discipline: the entry fires,
    /// `last_activity` decides, live connections are re-armed).
    idle_timers: TimerHeap,
    /// Live-connection count per peer IP (entries removed at zero);
    /// only maintained when `opts.max_conns_per_ip > 0`. Per-SHARD under
    /// sharding: a peer can hold up to `loop_shards *` the configured
    /// cap in the worst case — the cap is a flood guard, not a quota.
    per_ip: HashMap<IpAddr, usize>,
    accept_backoff_until: Option<Instant>,
    draining_since: Option<Instant>,
    /// Event buffer reused across poll rounds.
    events: Vec<Event>,
}

impl Shard {
    pub(super) fn new(s: ShardSetup) -> Shard {
        let (done_tx, done_rx) = mpsc::channel::<Done>();
        Shard {
            index: s.index,
            nshards: s.nshards,
            listener: s.listener,
            listener_registered: false,
            accept_mode: s.accept_mode,
            rr: 0,
            stop: s.stop,
            signal: s.signal,
            peers: s.peers,
            pipe_rx: s.pipe_rx,
            poller: s.poller,
            work_tx: s.work_tx,
            done_tx,
            done_rx,
            broker: s.broker,
            store: s.store,
            opts: s.opts,
            conns: HashMap::new(),
            conns_total: s.conns_total,
            next_id: s.index as u64,
            id_stride: s.nshards as u64,
            timers: TimerHeap::new(),
            idle_timers: TimerHeap::new(),
            per_ip: HashMap::new(),
            accept_backoff_until: None,
            draining_since: None,
            events: Vec::new(),
        }
    }

    pub(super) fn run(mut self) {
        if self
            .poller
            .register(self.pipe_rx.as_raw_fd(), TOKEN_PIPE, Interest::READABLE)
            .is_err()
        {
            obs::trace(
                "server.start",
                format!("shard {}: wake-pipe registration failed; shard down", self.index),
            );
            return;
        }
        obs::trace(
            "server.start",
            format!("shard {} serving on the {} backend", self.index, self.poller.name()),
        );
        loop {
            if self.stop.load(Ordering::SeqCst) && self.draining_since.is_none() {
                self.begin_drain();
            }
            self.adopt_handoffs();
            self.drain_done();
            self.drain_woken();
            self.fire_timers();
            if let Some(t0) = self.draining_since {
                if self.drained() || Instant::now() >= t0 + self.opts.drain_wait {
                    // Conns and this shard's work-channel clone drop here;
                    // once every shard has, workers see the closed channel
                    // and unwind.
                    return;
                }
            }
            self.poll_once();
        }
    }

    /// Stop accepting (close the listener NOW — remote Shutdown promises
    /// the port is closed shortly after the op returns), then give every
    /// parked op a final attempt so its client gets a legal empty answer
    /// instead of a cut connection.
    fn begin_drain(&mut self) {
        self.draining_since = Some(Instant::now());
        if let Some(listener) = self.listener.take() {
            if self.listener_registered {
                let _ = self.poller.deregister(listener.as_raw_fd());
                self.listener_registered = false;
            }
        }
        let parked: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| matches!(c.phase, Phase::Parked(_)))
            .map(|(&id, _)| id)
            .collect();
        let now = Instant::now();
        for id in parked {
            self.timers.note_stale();
            self.resume_parked(id, Some(now));
        }
    }

    /// Drain complete: nothing executing in a worker and every response
    /// buffer flushed (reading/parked conns hold no server-side work).
    fn drained(&self) -> bool {
        self.conns.values().all(|c| !matches!(c.phase, Phase::Executing) && !c.has_output())
    }

    /// Adopt sockets the accepting shard handed to this one. During a
    /// drain nothing is adopted: the socket drops (connection reset),
    /// exactly what a fresh connect against a closed listener would see.
    fn adopt_handoffs(&mut self) {
        for stream in self.signal.drain_handoff() {
            if self.draining_since.is_some() {
                self.conns_total.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            match stream.peer_addr() {
                Ok(peer) => self.admit(stream, peer),
                Err(_) => {
                    // Peer vanished between accept and adoption.
                    self.conns_total.fetch_sub(1, Ordering::SeqCst);
                }
            }
        }
    }

    /// Move a parked connection back to executing and re-dispatch its op.
    /// A `forced_deadline` (drain or timer expiry) makes the attempt
    /// final: the worker sees it as expired and responds with what's
    /// there, mirroring the blocking loop's deliver-then-check-deadline.
    fn resume_parked(&mut self, id: u64, forced_deadline: Option<Instant>) {
        let Some(conn) = self.conns.get_mut(&id) else { return };
        if !matches!(conn.phase, Phase::Parked(_)) {
            return;
        }
        let Phase::Parked(p) = std::mem::replace(&mut conn.phase, Phase::Executing) else {
            unreachable!()
        };
        obs::gauge_add(obs::Gauge::ServerConnsParked, -1);
        conn.wake_pending = false;
        let work = Work {
            conn: id,
            op: p.op,
            body: p.body,
            deadline: Some(forced_deadline.unwrap_or(p.deadline)),
            waker: conn.waker.clone(),
            enqueued: Instant::now(),
            done: self.done_tx.clone(),
        };
        // Drop the previous attempt's registration; the retry re-registers
        // if it parks again. (Wakes already consumed it in the common
        // case — cancelling is cheap and keeps the maps tidy.)
        cancel_site(&p.site, id, self.broker.as_ref(), &self.store);
        let _ = self.work_tx.send(work);
    }

    fn drain_done(&mut self) {
        while let Ok(done) = self.done_rx.try_recv() {
            let draining = self.draining_since.is_some();
            let mut close = false;
            {
                let Some(conn) = self.conns.get_mut(&done.conn) else { continue };
                match done.verdict {
                    Verdict::Respond(frame) => {
                        conn.phase = Phase::Reading;
                        conn.last_activity = Instant::now();
                        conn.queue_response(frame);
                        let ok = conn.flush_output();
                        close = !ok || (conn.close_after_write && !conn.has_output());
                    }
                    Verdict::Park { op, body, deadline, site } => {
                        if conn.wake_pending || draining {
                            // A waker fired mid-execution (or we are
                            // draining): retry immediately. Drain retries
                            // carry an expired deadline, making them final.
                            conn.wake_pending = false;
                            conn.phase = Phase::Executing;
                            let dl = if draining { Instant::now() } else { deadline };
                            cancel_site(&site, done.conn, self.broker.as_ref(), &self.store);
                            let work = Work {
                                conn: done.conn,
                                op,
                                body,
                                deadline: Some(dl),
                                waker: conn.waker.clone(),
                                enqueued: Instant::now(),
                                done: self.done_tx.clone(),
                            };
                            let _ = self.work_tx.send(work);
                        } else {
                            obs::inc(obs::Counter::ServerParks);
                            obs::gauge_add(obs::Gauge::ServerConnsParked, 1);
                            self.timers.arm(deadline, done.conn);
                            conn.phase = Phase::Parked(ParkedOp { op, body, deadline, site });
                        }
                    }
                }
            }
            if close {
                self.close_conn(done.conn);
            }
        }
    }

    fn drain_woken(&mut self) {
        for id in self.signal.drain_woken() {
            let resume = match self.conns.get_mut(&id) {
                Some(conn) => match conn.phase {
                    Phase::Parked(_) => true,
                    Phase::Executing => {
                        conn.wake_pending = true;
                        false
                    }
                    // Response already sent; the wake was consumed by a
                    // finished attempt. Nothing to re-check.
                    Phase::Reading => false,
                },
                // Closed since the wake was queued (ids are never reused).
                None => false,
            };
            if resume {
                // The heap entry for this park outlives the resume.
                self.timers.note_stale();
                self.resume_parked(id, None);
            }
        }
    }

    fn fire_timers(&mut self) {
        let now = Instant::now();
        while let Some((t, id)) = self.timers.peek() {
            if t > now {
                break;
            }
            self.timers.pop();
            let due = match self.conns.get(&id) {
                Some(c) => match &c.phase {
                    Phase::Parked(p) => p.deadline <= now,
                    _ => false,
                },
                None => false,
            };
            if due {
                self.resume_parked(id, Some(now));
            } else {
                self.timers.note_popped_stale();
            }
        }
        {
            let conns = &self.conns;
            self.timers.maybe_compact(|id, t| {
                matches!(conns.get(&id),
                    Some(c) if matches!(&c.phase, Phase::Parked(p) if p.deadline == t))
            });
        }
        self.reap_idle(now);
    }

    /// Idle-reap pass: pop due checkpoints; close a reading connection
    /// whose `last_activity` really is `idle_timeout` old, lazily re-arm
    /// everything else. Parked consumers (mid-op) and conns with buffered
    /// output (making progress / backpressured) are never reaped.
    fn reap_idle(&mut self, now: Instant) {
        let Some(idle) = self.opts.idle_timeout else { return };
        let mut reap = Vec::new();
        while let Some((t, id)) = self.idle_timers.peek() {
            if t > now {
                break;
            }
            self.idle_timers.pop();
            let Some(c) = self.conns.get(&id) else {
                self.idle_timers.note_popped_stale();
                continue;
            };
            let due = c.last_activity + idle;
            let reapable = matches!(c.phase, Phase::Reading) && !c.has_output();
            if reapable && due <= now {
                reap.push(id);
            } else if reapable {
                // Activity since this entry was pushed: re-arm at the
                // true due time.
                self.idle_timers.arm(due, id);
            } else {
                // Mid-op or flushing: not idle by definition. Check again
                // a full period later.
                self.idle_timers.arm(now + idle, id);
            }
        }
        {
            let conns = &self.conns;
            self.idle_timers.maybe_compact(|id, _| conns.contains_key(&id));
        }
        for id in reap {
            obs::inc(obs::Counter::ServerConnsReaped);
            obs::trace("server.reap", format!("conn {id}: no frame activity for {idle:?}"));
            self.close_conn(id);
        }
    }

    fn poll_timeout(&self, now: Instant) -> Duration {
        let mut t = IDLE_POLL;
        if let Some((dl, _)) = self.timers.peek() {
            t = t.min(dl.saturating_duration_since(now));
        }
        if let Some((dl, _)) = self.idle_timers.peek() {
            t = t.min(dl.saturating_duration_since(now));
        }
        if let Some(b) = self.accept_backoff_until {
            t = t.min(b.saturating_duration_since(now));
        }
        if let Some(t0) = self.draining_since {
            t = t.min((t0 + self.opts.drain_wait).saturating_duration_since(now));
        }
        t.max(Duration::from_millis(1))
    }

    fn poll_once(&mut self) {
        let now = Instant::now();
        let draining = self.draining_since.is_some();

        let backoff_over = match self.accept_backoff_until {
            Some(t) => t <= now,
            None => true,
        };
        if backoff_over {
            self.accept_backoff_until = None;
        }
        // The listener joins the interest set only while under the
        // (global) cap and not backed off: at the cap excess connects
        // wait in the OS backlog (no accept-then-close churn).
        let want_listener = self.listener.is_some()
            && backoff_over
            && self.conns_total.load(Ordering::SeqCst) < self.opts.max_connections;
        if want_listener != self.listener_registered {
            if let Some(listener) = &self.listener {
                let r = if want_listener {
                    self.poller.register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READABLE)
                } else {
                    self.poller.deregister(listener.as_raw_fd())
                };
                if r.is_ok() {
                    self.listener_registered = want_listener;
                }
            } else {
                self.listener_registered = false;
            }
        }

        // Reconcile connection interests with the poller (states changed
        // in drain_done/fire_timers since the last wait). A no-op
        // reconcile is a cached comparison, not a syscall — with epoll,
        // steady state costs zero syscalls here and the wait is O(ready).
        {
            let poller = &mut self.poller;
            for (&id, c) in self.conns.iter_mut() {
                let want = desired_interest(c, draining);
                if want != c.interest
                    && poller.modify(c.stream.as_raw_fd(), id as usize, want).is_ok()
                {
                    c.interest = want;
                }
            }
        }

        let timeout = self.poll_timeout(now);
        let mut events = std::mem::take(&mut self.events);
        events.clear();
        if self.poller.wait(timeout, &mut events).is_err() {
            // Transient poller failure: don't spin.
            std::thread::sleep(Duration::from_millis(5));
            self.events = events;
            return;
        }
        // Round duration = dispatch work after the wait, not the sleep.
        let round_start = Instant::now();
        for ev in &events {
            match ev.token {
                TOKEN_PIPE => self.drain_pipe(),
                TOKEN_LISTENER => self.accept_ready(),
                token => self.handle_conn_event(token as u64, *ev),
            }
        }
        let ns = round_start.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        obs::observe(obs::Hist::ServerPollRoundNs, ns);
        obs::shard_observe_poll_round(self.index, ns);
        self.events = events;
    }

    fn drain_pipe(&mut self) {
        let mut buf = [0u8; 256];
        loop {
            match self.pipe_rx.read(&mut buf) {
                Ok(0) => return,
                Ok(_) => {}
                Err(_) => return,
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            if self.conns_total.load(Ordering::SeqCst) >= self.opts.max_connections {
                return;
            }
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, peer)) => {
                    // Counted at accept; released on close, refusal, or a
                    // failed adoption — whichever shard gets the socket.
                    self.conns_total.fetch_add(1, Ordering::SeqCst);
                    match self.accept_mode {
                        AcceptMode::Own | AcceptMode::Handoff => self.admit(stream, peer),
                        AcceptMode::Distribute => {
                            let target = self.rr % self.nshards;
                            self.rr = self.rr.wrapping_add(1);
                            if target == self.index {
                                self.admit(stream, peer);
                            } else {
                                self.peers[target].hand_off(stream);
                            }
                        }
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    // EMFILE and friends: pause accepting briefly, and
                    // count it — a climbing rate here is fd exhaustion,
                    // which is otherwise silent.
                    obs::inc(obs::Counter::ServerAcceptBackoffs);
                    self.accept_backoff_until = Some(Instant::now() + ACCEPT_BACKOFF);
                    return;
                }
            }
        }
    }

    /// Take ownership of an accepted socket: per-IP admission, poller
    /// registration, connection table entry. The `conns_total` slot was
    /// claimed at accept time; every refusal path here releases it.
    fn admit(&mut self, stream: TcpStream, peer: SocketAddr) {
        let peer_ip = (self.opts.max_conns_per_ip > 0).then(|| peer.ip());
        if let Some(ip) = peer_ip {
            let live = self.per_ip.get(&ip).copied().unwrap_or(0);
            if live >= self.opts.max_conns_per_ip {
                // Refuse outright (drop closes the socket): parking this
                // peer in the backlog would let it starve everyone
                // else's slots.
                drop(stream);
                self.conns_total.fetch_sub(1, Ordering::SeqCst);
                obs::inc(obs::Counter::ServerConnsRefused);
                obs::shard_inc_refused(self.index);
                return;
            }
            *self.per_ip.entry(ip).or_insert(0) += 1;
        }
        if stream.set_nonblocking(true).is_err() {
            if let Some(ip) = peer_ip {
                self.release_ip(ip);
            }
            self.conns_total.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        let _ = stream.set_nodelay(true);
        let id = self.next_id;
        self.next_id += self.id_stride;
        if self.poller.register(stream.as_raw_fd(), id as usize, Interest::READABLE).is_err() {
            if let Some(ip) = peer_ip {
                self.release_ip(ip);
            }
            self.conns_total.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        let now = Instant::now();
        let waker = Arc::new(ConnWaker { conn: id, signal: self.signal.clone() });
        self.conns.insert(
            id,
            Conn {
                stream,
                peer_ip,
                asm: FrameAssembler::new(),
                phase: Phase::Reading,
                out: Vec::new(),
                out_pos: 0,
                wake_pending: false,
                close_after_write: false,
                waker,
                last_activity: now,
                interest: Interest::READABLE,
            },
        );
        obs::inc(obs::Counter::ServerConnsAccepted);
        obs::shard_inc_accepted(self.index);
        obs::gauge_add(obs::Gauge::ServerConnsLive, 1);
        obs::shard_conns_add(self.index, 1);
        if let Some(idle) = self.opts.idle_timeout {
            self.idle_timers.arm(now + idle, id);
        }
    }

    fn handle_conn_event(&mut self, id: u64, ev: Event) {
        let next = {
            let Some(conn) = self.conns.get_mut(&id) else { return };
            conn.last_activity = Instant::now();
            if conn.has_output() {
                // Writable (or the error surfaces on write): keep flushing.
                if ev.writable || ev.error {
                    if !conn.flush_output() {
                        Next::Close
                    } else if !conn.has_output() && conn.close_after_write {
                        Next::Close
                    } else {
                        Next::Keep
                    }
                } else {
                    Next::Keep
                }
            } else if matches!(conn.phase, Phase::Executing) {
                // Not watched while executing; a stale event can only be
                // a leftover from the round that dispatched. Ignore it —
                // acting here could close a connection whose waiter
                // registration the in-flight op still owns.
                Next::Keep
            } else if ev.readable || ev.error {
                if matches!(conn.phase, Phase::Parked(_)) {
                    Self::parked_readable(id, conn)
                } else {
                    // Errors still go through read(): the peer may have
                    // sent a final request, and read() reports the error.
                    Self::read_next(conn)
                }
            } else {
                Next::Keep
            }
        };
        match next {
            Next::Keep => {}
            Next::Close => self.close_conn(id),
            Next::Dispatch(op, body) => self.dispatch(id, op, body),
            Next::Shutdown => self.remote_shutdown(id),
        }
    }

    /// A parked connection's socket turned readable. The protocol is
    /// synchronous — one request in flight, and this one is still parked —
    /// so the only legal peer behavior is silence: EOF/RST means the
    /// volunteer died, and actual bytes are a protocol violation. Either
    /// way the connection is torn down NOW, which cancels its broker/store
    /// waiter registration (via `close_conn`) instead of leaking it until
    /// the park deadline expires.
    fn parked_readable(id: u64, conn: &mut Conn) -> Next {
        let mut probe = [0u8; 64];
        match conn.stream.read(&mut probe) {
            Ok(0) => {
                obs::trace("server.dead_waiter", format!("conn {id}: peer hung up while parked"));
                Next::Close
            }
            Ok(n) => {
                obs::trace(
                    "server.dead_waiter",
                    format!("conn {id}: {n} bytes while an op was parked (protocol violation)"),
                );
                Next::Close
            }
            // Spurious wakeup (e.g. an error event that read() doesn't
            // surface yet): leave the park in place.
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => Next::Keep,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => Next::Keep,
            Err(_) => {
                obs::trace("server.dead_waiter", format!("conn {id}: read error while parked"));
                Next::Close
            }
        }
    }

    /// Drive the frame assembler; at most one decoded frame per call (the
    /// protocol is synchronous — the next frame is read after responding).
    fn read_next(conn: &mut Conn) -> Next {
        let mut counted = CountingReader { inner: &mut conn.stream, n: 0 };
        let polled = conn.asm.poll_read(&mut counted, READ_BUDGET);
        if counted.n >= READ_BUDGET {
            // The frame outran this round's fairness budget; the rest
            // arrives on later readiness. Worth counting: a sustained rate
            // here means one firehose peer is rationed by the loop.
            obs::inc(obs::Counter::ServerReadBudgetExhausted);
        }
        match polled {
            Ok(Some((op_byte, body))) => match Op::from_u8(op_byte) {
                Ok(Op::Shutdown) => Next::Shutdown,
                Ok(op) => Next::Dispatch(op, body),
                Err(e) => {
                    // Unknown opcode: error response, connection lives on.
                    conn.queue_response(frame_bytes(ST_ERR, e.to_string().as_bytes()));
                    if conn.flush_output() {
                        Next::Keep
                    } else {
                        Next::Close
                    }
                }
            },
            Ok(None) => Next::Keep, // mid-frame; resume on next readiness
            Err(_) => Next::Close,  // disconnect, truncation, bad length
        }
    }

    fn dispatch(&mut self, id: u64, op: Op, body: Vec<u8>) {
        let Some(conn) = self.conns.get_mut(&id) else { return };
        conn.phase = Phase::Executing;
        // A wake left over from the previous (already answered) op must
        // not count against this one.
        conn.wake_pending = false;
        obs::inc(obs::Counter::ServerOps);
        let work = Work {
            conn: id,
            op,
            body,
            deadline: None,
            waker: conn.waker.clone(),
            enqueued: Instant::now(),
            done: self.done_tx.clone(),
        };
        let _ = self.work_tx.send(work);
    }

    /// Remote Shutdown: set the stop flag (every shard's next loop turn
    /// starts its drain — the peers are poked awake), acknowledge with
    /// ST_OK, and close this connection once the ack is flushed.
    fn remote_shutdown(&mut self, id: u64) {
        self.stop.store(true, Ordering::SeqCst);
        for peer in &self.peers {
            peer.notify();
        }
        let mut close = false;
        if let Some(conn) = self.conns.get_mut(&id) {
            conn.queue_response(frame_bytes(ST_OK, &[]));
            conn.close_after_write = true;
            close = !conn.flush_output() || !conn.has_output();
        }
        if close {
            self.close_conn(id);
        }
    }

    fn close_conn(&mut self, id: u64) {
        if let Some(conn) = self.conns.remove(&id) {
            // Deregister BEFORE the fd closes: the poll backend keeps its
            // own table and would spin on a dead descriptor.
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.conns_total.fetch_sub(1, Ordering::SeqCst);
            obs::inc(obs::Counter::ServerConnsClosed);
            obs::gauge_add(obs::Gauge::ServerConnsLive, -1);
            obs::shard_conns_add(self.index, -1);
            if self.opts.idle_timeout.is_some() {
                self.idle_timers.note_stale();
            }
            if let Some(ip) = conn.peer_ip {
                self.release_ip(ip);
            }
            if let Phase::Parked(p) = &conn.phase {
                obs::gauge_add(obs::Gauge::ServerConnsParked, -1);
                self.timers.note_stale();
                cancel_site(&p.site, id, self.broker.as_ref(), &self.store);
            }
        }
    }

    /// Release one per-IP accounting slot (entries vanish at zero so the
    /// map tracks only currently-connected peers).
    fn release_ip(&mut self, ip: IpAddr) {
        if let Some(n) = self.per_ip.get_mut(&ip) {
            *n -= 1;
            if *n == 0 {
                self.per_ip.remove(&ip);
            }
        }
    }
}

/// Counts bytes flowing through [`FrameAssembler::poll_read`] so the
/// caller can tell "stream ran dry" from "fairness budget exhausted" —
/// the assembler reports both as `Ok(None)`.
struct CountingReader<'a, R: Read> {
    inner: &'a mut R,
    n: usize,
}

impl<R: Read> Read for CountingReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.n += n;
        Ok(n)
    }
}

pub(super) fn worker_loop(
    work_rx: &Mutex<mpsc::Receiver<Work>>,
    broker: &dyn QueueService,
    store: &Store,
) {
    loop {
        // Standard shared-receiver pool: the lock is held only while
        // waiting for/taking an item, never while executing it.
        let msg = { work_rx.lock().unwrap().recv() };
        let Ok(work) = msg else { return }; // every shard has shut down
        let conn = work.conn;
        let done_tx = work.done.clone();
        let signal = work.waker.signal.clone();
        obs::observe_since(obs::Hist::ServerOpQueueWaitNs, work.enqueued);
        let exec_start = Instant::now();
        // A panicking op (poisoned lock, arithmetic bug) must not shrink
        // the pool: convert it to an in-band error response.
        let verdict = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_work(work, broker, store)
        }))
        .unwrap_or_else(|_| Verdict::Respond(frame_bytes(ST_ERR, b"internal server error")));
        obs::observe_since(obs::Hist::ServerOpExecuteNs, exec_start);
        if done_tx.send(Done { conn, verdict }).is_ok() {
            signal.notify();
        }
        // A failed send means that one shard already exited (shutdown
        // race); the pool keeps serving the remaining shards.
    }
}

/// Execute one decoded request. Blocking ops (Consume / ConsumeMany /
/// WaitVersion) run the register-then-try protocol: register a waker,
/// re-check with a zero timeout, park on empty — the worker never sleeps.
fn run_work(work: Work, broker: &dyn QueueService, store: &Store) -> Verdict {
    let Work { conn, op, body, deadline, waker, .. } = work;
    let now = Instant::now();
    let (site, deadline, expired) = match blocking_site(op, &body) {
        Some((site, timeout)) => {
            let dl = deadline.unwrap_or_else(|| now + timeout.min(MAX_BLOCK));
            (Some(site), dl, now >= dl)
        }
        None => (None, now, false),
    };
    if !expired {
        if let Some(site) = &site {
            let registered = match site {
                WaitSite::Queue(q) => broker.register_waiter(q, conn, waker.clone()),
                WaitSite::Version => {
                    store.register_waiter(conn, waker.clone());
                    Ok(())
                }
            };
            if let Err(e) = registered {
                // e.g. consume on an undeclared queue — the same error
                // the op itself would report.
                return Verdict::Respond(frame_bytes(ST_ERR, e.to_string().as_bytes()));
            }
        }
    }
    match execute_op_with(op, &body, broker, store, TimeoutMode::Immediate) {
        Ok((st, resp)) => match site {
            Some(site) if st == ST_NONE && !expired => Verdict::Park { op, body, deadline, site },
            Some(site) => {
                cancel_site(&site, conn, broker, store);
                Verdict::Respond(frame_bytes(st, &resp))
            }
            None => Verdict::Respond(frame_bytes(st, &resp)),
        },
        Err(e) => {
            if let Some(site) = &site {
                cancel_site(site, conn, broker, store);
            }
            Verdict::Respond(frame_bytes(ST_ERR, e.to_string().as_bytes()))
        }
    }
}

/// `(wait site, protocol timeout)` for ops that may block; `None` for
/// everything else — including malformed bodies, which fall through to
/// [`execute_op_with`] for the verbatim parse error.
fn blocking_site(op: Op, body: &[u8]) -> Option<(WaitSite, Duration)> {
    let mut r = BodyReader::new(body);
    match op {
        Op::Consume => {
            let q = r.str().ok()?.to_string();
            Some((WaitSite::Queue(q), Duration::from_millis(r.u64().ok()?)))
        }
        Op::ConsumeMany => {
            let q = r.str().ok()?.to_string();
            r.u64().ok()?; // max batch size
            Some((WaitSite::Queue(q), Duration::from_millis(r.u64().ok()?)))
        }
        Op::WaitVersion => {
            r.str().ok()?;
            r.u64().ok()?; // min version
            Some((WaitSite::Version, Duration::from_millis(r.u64().ok()?)))
        }
        _ => None,
    }
}

fn cancel_site(site: &WaitSite, conn: u64, broker: &dyn QueueService, store: &Store) {
    match site {
        WaitSite::Queue(q) => broker.cancel_waiter(q, conn),
        WaitSite::Version => store.cancel_waiter(conn),
    }
}

/// Frame a response the way the client reads it: `[len u32][status][body]`.
pub(super) fn frame_bytes(status: u8, body: &[u8]) -> Vec<u8> {
    if 1 + body.len() > MAX_FRAME {
        // Mirror write_frame's cap: answer with the error instead of
        // emitting a frame the client would reject as corrupt.
        let msg = format!("frame too large: {} bytes", 1 + body.len());
        return frame_bytes(ST_ERR, msg.as_bytes());
    }
    let mut out = Vec::with_capacity(5 + body.len());
    out.extend_from_slice(&((1 + body.len()) as u32).to_le_bytes());
    out.push(status);
    out.extend_from_slice(body);
    out
}

/// Bind `addr` with `SO_REUSEPORT` set before the bind, so several shard
/// listeners can share one port and the kernel balances accepts across
/// them by connection-tuple hash. Hand-rolled FFI under the same
/// dependency budget as the pollers. Caveat: kernel balancing is by
/// hash, not load — a shard that falls behind still receives its share,
/// which is why per-shard `obs` gauges exist.
#[cfg(target_os = "linux")]
pub(super) fn bind_reuseport(addr: &SocketAddr) -> io::Result<TcpListener> {
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::FromRawFd;

    const AF_INET: c_int = 2;
    const AF_INET6: c_int = 10;
    const SOCK_STREAM: c_int = 1;
    const SOCK_CLOEXEC: c_int = 0o2000000;
    const SOL_SOCKET: c_int = 1;
    const SO_REUSEADDR: c_int = 2;
    const SO_REUSEPORT: c_int = 15;
    const BACKLOG: c_int = 1024;

    #[repr(C)]
    struct SockAddrIn {
        family: u16,
        port: u16,
        addr: u32,
        zero: [u8; 8],
    }

    #[repr(C)]
    struct SockAddrIn6 {
        family: u16,
        port: u16,
        flowinfo: u32,
        addr: [u8; 16],
        scope_id: u32,
    }

    extern "C" {
        fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            value: *const c_void,
            len: u32,
        ) -> c_int;
        fn bind(fd: c_int, addr: *const c_void, len: u32) -> c_int;
        fn listen(fd: c_int, backlog: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    let domain = if addr.is_ipv4() { AF_INET } else { AF_INET6 };
    let fd = unsafe { socket(domain, SOCK_STREAM | SOCK_CLOEXEC, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let fail = |fd: c_int| -> io::Error {
        let e = io::Error::last_os_error();
        unsafe { close(fd) };
        e
    };
    let one: c_int = 1;
    for opt in [SO_REUSEADDR, SO_REUSEPORT] {
        let rc = unsafe {
            setsockopt(fd, SOL_SOCKET, opt, &one as *const c_int as *const c_void, 4)
        };
        if rc < 0 {
            return Err(fail(fd));
        }
    }
    let rc = match addr {
        SocketAddr::V4(v4) => {
            let sa = SockAddrIn {
                family: AF_INET as u16,
                port: v4.port().to_be(),
                addr: u32::from(*v4.ip()).to_be(),
                zero: [0; 8],
            };
            unsafe {
                bind(
                    fd,
                    &sa as *const SockAddrIn as *const c_void,
                    std::mem::size_of::<SockAddrIn>() as u32,
                )
            }
        }
        SocketAddr::V6(v6) => {
            let sa = SockAddrIn6 {
                family: AF_INET6 as u16,
                port: v6.port().to_be(),
                flowinfo: 0,
                addr: v6.ip().octets(),
                scope_id: v6.scope_id(),
            };
            unsafe {
                bind(
                    fd,
                    &sa as *const SockAddrIn6 as *const c_void,
                    std::mem::size_of::<SockAddrIn6>() as u32,
                )
            }
        }
    };
    if rc < 0 {
        return Err(fail(fd));
    }
    if unsafe { listen(fd, BACKLOG) } < 0 {
        return Err(fail(fd));
    }
    let listener = unsafe { TcpListener::from_raw_fd(fd) };
    listener.set_nonblocking(true)?;
    Ok(listener)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite bugfix regression: a connection that repeatedly parks
    /// and is woken before its deadline used to leave one dead heap
    /// entry per cycle — unbounded growth for a long-lived chatty
    /// consumer. With stale-count compaction the heap stays at a small
    /// constant independent of the cycle count.
    #[test]
    fn timer_heap_stays_bounded_across_park_wake_cycles() {
        let mut th = TimerHeap::new();
        let deadline = Instant::now() + Duration::from_secs(3600);
        let mut max_len = 0;
        for _ in 0..10_000 {
            // Park: arm a deadline entry. Wake before the deadline: the
            // entry goes stale in place (exactly what drain_woken does).
            th.arm(deadline, 1);
            th.note_stale();
            th.maybe_compact(|_, _| false);
            max_len = max_len.max(th.len());
        }
        assert!(max_len <= 16, "timer heap grew to {max_len} entries over park/wake cycles");
        assert!(th.len() <= 16);
    }

    #[test]
    fn timer_heap_compaction_keeps_live_entries() {
        let mut th = TimerHeap::new();
        let deadline = Instant::now() + Duration::from_secs(3600);
        th.arm(deadline, 2); // the one live wait
        for _ in 0..100 {
            th.arm(deadline, 1);
            th.note_stale();
            th.maybe_compact(|id, _| id == 2);
        }
        assert!(th.len() <= 16);
        assert!(
            th.heap.iter().any(|&Reverse((_, id))| id == 2),
            "compaction must keep the live entry"
        );
    }

    #[test]
    fn blocking_site_parses_only_blocking_ops() {
        let mut c = super::super::body_with_name("jobs", &[]);
        c.extend_from_slice(&250u64.to_le_bytes());
        match blocking_site(Op::Consume, &c) {
            Some((WaitSite::Queue(q), t)) => {
                assert_eq!(q, "jobs");
                assert_eq!(t, Duration::from_millis(250));
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert!(blocking_site(Op::Publish, &c).is_none());
        // Malformed body: not a blocking site; the executor reports it.
        assert!(blocking_site(Op::Consume, &[1, 2]).is_none());
    }

    #[test]
    fn frame_bytes_caps_oversize_responses() {
        let f = frame_bytes(ST_OK, &vec![0u8; MAX_FRAME]);
        // Replaced by an in-band error frame the client can parse.
        assert_eq!(f[4], ST_ERR);
        let len = u32::from_le_bytes(f[0..4].try_into().unwrap()) as usize;
        assert_eq!(len, f.len() - 4);
        assert!(len <= MAX_FRAME);
    }
}
