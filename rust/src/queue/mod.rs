//! QueueServer substrate (S1, paper §IV.D) — the RabbitMQ stand-in.
//!
//! JSDoop relies on a small AMQP subset: named FIFO queues, explicit ACK
//! ("tasks are not removed from the queue until an ACK is received"), a
//! per-task visibility timeout after which an unACKed task is requeued
//! (paper §II.E *Adaptability*: "if a task is not resolved within the
//! maximum time, it is added back to the pending queue"), and multiple
//! specialized queues for load balancing. [`broker::Broker`] implements it
//! in-process; [`server`]/[`client`] expose the same API over TCP
//! ([`wire`] frames — the STOMP-over-WebSocket stand-in) so volunteers can
//! run as separate OS processes, and [`QueueApi`] makes the two
//! interchangeable to the agents.
//!
//! # Durability & recovery
//!
//! JSDoop inherits crash tolerance from RabbitMQ's durable queues: "tasks
//! are not removed from the queue until an ACK is received" holds *across
//! a broker restart* there. [`durability::DurableBroker`] closes that gap
//! for the in-process broker: every mutation (declare / publish /
//! publish_many / delivery / ack / nack / purge) is appended to a
//! length-prefixed, CRC-checked write-ahead log, and the log is
//! periodically compacted into a [`broker::Broker::snapshot`]-format base
//! file. Recovery replays snapshot + log tail into a fresh broker:
//! acknowledged messages never reappear, every unACKed or ready message
//! survives exactly once in FIFO-per-priority order, and messages that
//! had been delivered before the crash come back with
//! `redelivered = true`.
//!
//! What is and isn't synced to disk is governed by
//! [`durability::SyncPolicy`]:
//!
//! - `Always` — an operation returns only once the durable watermark
//!   covers its record: it survives both process SIGKILL and power loss.
//!   Commits are group committed (an elected leader fsyncs outside the
//!   log mutex), so concurrent committers share one fsync.
//! - `EveryN(n)` — fsync roughly once per n records. Every append is
//!   still flushed to the OS before the op returns, so SIGKILL loses
//!   nothing confirmed; only power loss can take the unsynced window.
//! - `Never` — durability off: nothing is journaled; state persists only
//!   through snapshot compaction (explicit, or on graceful shutdown). In
//!   exchange the hot path is required (and bench-enforced, see
//!   `benches/durability.rs`) to stay within 5% of the plain
//!   [`broker::Broker`].
//!
//! Recovery is idempotent by construction — WAL records carry message
//! *identities* ((priority, seq), never reused), so replaying a record
//! whose effect is already captured in the snapshot is a no-op. That is
//! what lets compaction run concurrently with live traffic without
//! quiescing the broker.
//!
//! # Replication
//!
//! The paper's broker also survives NODE loss, because RabbitMQ itself
//! can be clustered. [`durability::replication`] closes that half:
//! a follower (`jsdoop serve --durability_dir=F --replicate-from=ADDR`)
//! pulls the primary's log over the ordinary wire protocol
//! (`ReplHandshake` / `ReplSnapshot` / `ReplPull` ops) and mirrors it
//! byte-for-byte into its own durability directory.
//!
//! Topology and what ships when:
//!
//! - Only **fsync-covered** WAL bytes ship (the primary tracks a
//!   byte-level durable watermark next to the record-level one group
//!   commit introduced), so a follower only ever holds a prefix of
//!   CONFIRMED history — under `sync_policy=always` that prefix covers
//!   every acknowledged operation; under `every=N` it trails by at most
//!   the fsync window.
//! - Snapshot compaction bumps a segment *generation*; the follower
//!   detects it (or a primary restart) on its next pull and re-baselines
//!   from the new snapshot, which covers everything the old segment
//!   held. Replay is idempotent and append-order-independent, so a chunk
//!   applied twice is harmless.
//! - While following, the replica's server is READ-ONLY: `Stats`/`Len`
//!   answer from the live mirrored state (ready = survivors; unACKed
//!   messages fold back to ready, which is also what a promotion
//!   serves); every mutating op — queue AND data-store (the DataServer
//!   is not replicated in v0) — is rejected. The mirror directory
//!   carries a `replica.lock` marker so it cannot be served as a primary
//!   by accident, and a directory already holding a non-mirror
//!   durability history refuses to become one.
//!
//! Promotion (`jsdoop serve --durability_dir=F --promote`) clears the
//! marker and recovers the mirror exactly like a crashed primary: acked
//! messages never reappear, no (priority, seq) is ever re-issued
//! (the mirrored snapshot header carries the seq high-water mark), and
//! previously delivered messages redeliver flagged. Because replication
//! is asynchronous, a follower promoted after a primary death serves the
//! durable REPLICATED prefix — operations confirmed by the primary but
//! not yet shipped are lost with it, the standard async-replication
//! trade. Multi-follower fan-out and automatic failover are follow-ons
//! (ROADMAP); both build on these same three ops.
//!
//! # Serving at volunteer scale
//!
//! The paper's deployments lean on the browser-facing middleware to fan
//! thousands of volunteers into RabbitMQ; this reproduction's [`server`]
//! carries that load directly, so it is readiness-driven rather than
//! thread-per-connection: event-loop threads multiplex every socket
//! through a pluggable readiness backend (`poll(2)` everywhere; `epoll`
//! on Linux, where its O(ready) wait cost carries 50k idle volunteers),
//! a fixed worker pool executes decoded ops, and a
//! blocked consumer costs a parked *registration* — a [`ReadyWaker`]
//! lodged with the broker ([`QueueService::register_waiter`]) or store —
//! instead of a sleeping thread. Wakers follow a register-THEN-recheck
//! protocol (register first, then try the op with a zero timeout) so a
//! publish racing the park can never be a lost wakeup; wakes are
//! one-shot and may be spurious, and every notify site in the broker
//! (publish, nack, requeue sweep, purge…) fires them alongside its
//! `Condvar` broadcast so in-process and remote waiters stay equivalent.
//!
//! Two lifecycle rules keep a churny volunteer fleet from leaking server
//! state:
//!
//! - **Dead waiters are cancelled eagerly.** When a parked consumer's
//!   connection dies (POLLHUP / read error), the event loop tears the
//!   connection down immediately and cancels its broker/store waiter
//!   registration — a vanished volunteer stops counting against
//!   `max_connections` and its waiter entry right away, instead of
//!   lingering until the park deadline would have expired.
//! - **Idle connections are reaped.** With `--idle_timeout=N`, a
//!   connection with no frame activity for N seconds is closed by the
//!   same lazily-invalidated timer heap that drives park deadlines
//!   (counted in the `server.conns_reaped` metric) — so a slow-loris
//!   peer, or a browser tab that silently went away, cannot hold a file
//!   descriptor forever. Parked consumers are exempt: waiting for work
//!   is their job, and their park deadline already bounds them.
//!
//! Past one loop thread, `--loop_shards=N` splits the fleet across N
//! event loops — each shard owns its connections, timer heap, and waker
//! registrations outright (no cross-shard locking on the readiness
//! path), accepting via per-shard `SO_REUSEPORT` listeners where the
//! kernel provides them and an accept-and-hand-off round-robin where it
//! does not. The worker pool stays global, so a burst on one shard still
//! draws on every core. Backend selection (`--poller=auto|poll|epoll`),
//! the `Poller` trait contract, and the sharding topology are documented
//! at the top of [`server`].
//!
//! Connection lifecycle, write backpressure, and shutdown-drain rules
//! are documented at the top of [`server`]; live counters for all of the
//! above — including per-shard accept/refuse/poll-round gauges — are
//! served by `Op::Metrics` (see [`crate::obs`]).

pub mod broker;
pub mod client;
pub mod durability;
pub mod job;
pub mod server;
pub mod sharded;
pub mod wire;

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

/// One message handed to a consumer; must be ACKed (or NACKed) by `tag`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Delivery {
    pub tag: u64,
    pub payload: Vec<u8>,
    /// True if this delivery is a retry (visibility timeout or NACK).
    pub redelivered: bool,
}

/// Per-queue counters (metrics + ablation benches).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub published: u64,
    pub delivered: u64,
    pub acked: u64,
    pub nacked: u64,
    pub redelivered: u64,
    pub ready: usize,
    pub unacked: usize,
}

/// Priority used by plain [`QueueApi::publish`]: queues where every
/// message has this priority behave exactly FIFO.
pub const DEFAULT_PRIORITY: u64 = 1 << 62;

/// Wakeup token for a readiness-driven consumer: the TCP server's event
/// loop registers one per parked connection instead of a thread sleeping
/// in [`QueueApi::consume`]'s condvar. `wake` must be cheap, non-blocking,
/// and safe to call from any thread — the broker invokes it outside its
/// queue locks whenever messages become ready (publish, NACK, visibility
/// expiry). Wakeups are one-shot (registration is consumed by the wake)
/// and may be spurious; waiters re-check readiness and re-register.
pub trait ReadyWaker: Send + Sync {
    fn wake(&self);
}

/// What the TCP [`server`] hosts: the queue operations (plain AND
/// job-scoped — see [`job::JobQueueApi`]) plus the periodic visibility
/// sweep. Implemented by the plain in-process [`broker::Broker`] and
/// the WAL-backed [`durability::DurableBroker`], so one `serve` call
/// hosts either.
pub trait QueueService: job::JobQueueApi {
    /// Requeue expired unACKed messages (no-op default for backends that
    /// sweep internally).
    fn sweep(&self) {}

    /// The WAL-backed broker behind this service, if replication can be
    /// served from it ([`durability::DurableBroker`] overrides). The TCP
    /// server answers `ReplHandshake`/`ReplSnapshot`/`ReplPull` through
    /// this; `None` (plain broker, replica) rejects those ops.
    fn replication(&self) -> Option<&durability::DurableBroker> {
        None
    }

    /// Register a one-shot [`ReadyWaker`] for `queue`, keyed by `id`
    /// (re-registering under the same id replaces the previous waker).
    /// Errors if the queue does not exist — same contract as `consume`.
    ///
    /// Callers follow register-THEN-try: register the waker first, then
    /// attempt a nonblocking consume. A publish landing between the two
    /// steps fires the (already visible) waker, so no wakeup is lost.
    ///
    /// The default is a no-op: backends that reject blocking consume
    /// anyway (the read-only replica broker) never park a waiter, and a
    /// no-op registration just means such a consumer would rely on its
    /// deadline — which it never reaches, because the consume errors.
    fn register_waiter(&self, queue: &str, id: u64, waker: Arc<dyn ReadyWaker>) -> Result<()> {
        let _ = (queue, id, waker);
        Ok(())
    }

    /// Drop the waiter registered under (`queue`, `id`), if any. Unknown
    /// queues and ids are ignored — cancel races an in-flight wake.
    fn cancel_waiter(&self, queue: &str, id: u64) {
        let _ = (queue, id);
    }

    /// Per-queue live rows for the `Op::Metrics` snapshot: counters plus
    /// current depth/inflight/waiter state. Computed at snapshot time —
    /// the hot path never touches a per-queue metrics map. The default
    /// (no queues) suits backends with nothing to report.
    fn metrics_queues(&self) -> Vec<crate::obs::QueueMetrics> {
        Vec::new()
    }
}

impl QueueService for broker::Broker {
    fn sweep(&self) {
        broker::Broker::sweep(self)
    }

    fn register_waiter(&self, queue: &str, id: u64, waker: Arc<dyn ReadyWaker>) -> Result<()> {
        broker::Broker::register_waiter(self, queue, id, waker)
    }

    fn cancel_waiter(&self, queue: &str, id: u64) {
        broker::Broker::cancel_waiter(self, queue, id)
    }

    fn metrics_queues(&self) -> Vec<crate::obs::QueueMetrics> {
        broker::Broker::metrics_queues(self)
    }
}

/// The queue operations JSDoop needs, implemented by both the in-process
/// [`broker::Broker`] and the TCP [`client::RemoteQueue`].
pub trait QueueApi: Send + Sync {
    /// Create the queue if it does not exist (idempotent).
    fn declare(&self, queue: &str) -> Result<()>;
    /// Append a message at [`DEFAULT_PRIORITY`] (FIFO behaviour).
    fn publish(&self, queue: &str, payload: &[u8]) -> Result<()>;
    /// Append a message with an explicit priority (lower = served first).
    /// The Initiator publishes tasks with priority = batch order so
    /// redelivered/handed-back tasks can never be buried behind later
    /// batches (RabbitMQ `x-max-priority` analog).
    fn publish_pri(&self, queue: &str, payload: &[u8], priority: u64) -> Result<()>;
    /// Pop the head message, holding it unACKed under a visibility
    /// deadline. Blocks up to `timeout`; `None` on timeout.
    fn consume(&self, queue: &str, timeout: Duration) -> Result<Option<Delivery>>;
    /// Settle a delivery (removes it permanently).
    fn ack(&self, queue: &str, tag: u64) -> Result<()>;
    /// Return a delivery to its ORIGINAL queue position (voluntary
    /// hand-back: "I cannot or should not run this yet"). Used by the
    /// agents' priority-swap escape: a worker parked on a future model
    /// version probes the head, and if the head task precedes its own it
    /// nacks its held task and runs the earlier one. With priority
    /// ordering the hand-back can never bury earlier work.
    fn nack(&self, queue: &str, tag: u64) -> Result<()>;
    /// Ready-message count.
    fn len(&self, queue: &str) -> Result<usize>;
    /// Drop all ready + unacked messages.
    fn purge(&self, queue: &str) -> Result<()>;
    /// Counters snapshot.
    fn stats(&self, queue: &str) -> Result<QueueStats>;

    // --- batched operations ----------------------------------------------
    //
    // Gradient exchange arrives in bursts (16+ pushes per training batch),
    // and one wire roundtrip per message is the scalability ceiling the
    // paper's §II.E multi-QueueServer plan attacks. The batch entry points
    // move one *batch* per lock acquisition / wire frame. Defaults fall
    // back to loops of single ops, so every QueueApi impl keeps the exact
    // same observable semantics (property-tested in
    // rust/tests/prop_invariants.rs); Broker, RemoteQueue, and
    // ShardedQueue override them natively.

    /// Publish a batch at [`DEFAULT_PRIORITY`], in slice order.
    fn publish_many(&self, queue: &str, payloads: &[&[u8]]) -> Result<()> {
        for p in payloads {
            self.publish(queue, p)?;
        }
        Ok(())
    }

    /// Pop up to `max` messages in (priority, seq) service order, each held
    /// unACKed under its own visibility deadline. Blocks up to `timeout`
    /// for the FIRST message only; whatever else is ready at that moment
    /// rides along. Empty result on timeout.
    fn consume_many(&self, queue: &str, max: usize, timeout: Duration) -> Result<Vec<Delivery>> {
        let mut out = Vec::new();
        if max == 0 {
            return Ok(out);
        }
        match self.consume(queue, timeout)? {
            Some(d) => out.push(d),
            None => return Ok(out),
        }
        while out.len() < max {
            match self.consume(queue, Duration::ZERO)? {
                Some(d) => out.push(d),
                None => break,
            }
        }
        Ok(out)
    }

    /// Settle a batch of deliveries (each tag as [`QueueApi::ack`]).
    fn ack_many(&self, queue: &str, tags: &[u64]) -> Result<()> {
        for t in tags {
            self.ack(queue, *t)?;
        }
        Ok(())
    }

    /// Return a batch of deliveries to their original positions (each tag
    /// as [`QueueApi::nack`]).
    fn nack_many(&self, queue: &str, tags: &[u64]) -> Result<()> {
        for t in tags {
            self.nack(queue, *t)?;
        }
        Ok(())
    }
}
