//! Sharded QueueServer (paper §II.E, Scalability): "it is possible to use
//! several QueueServers in which each one stores a different type of task
//! ... A different server can host each queue, and we can use a load
//! balancer to choose the correct queue."
//!
//! [`ShardedQueue`] is that load balancer: it routes each QUEUE NAME to
//! one of N backends via rendezvous (highest-random-weight) hashing, so
//! adding a shard only remaps ~1/N of the queues and every client derives
//! the same placement independently — no routing table to distribute.
//! Backends are any [`JobQueueApi`] (in-process brokers, TCP clients, or
//! a mix), so the training run's heavy per-batch gradient queues can live
//! on different servers than the task queue.
//!
//! Job-scoped ops route by the QUALIFIED name (`"job/queue"`) — the same
//! string the plain settlement ops (consume/ack/len/...) are called with
//! afterwards — so a job queue's publishes and acks always meet on one
//! shard, and a single-job deployment's placement is byte-for-byte the
//! routing it always had.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::durability::{DurabilityOptions, DurableBroker};
use super::job::{self, JobInfo, JobQueueApi, JobQuota};
use super::{Delivery, QueueApi, QueueStats};

/// Stateless queue-name -> shard router + fan-out for the QueueApi.
pub struct ShardedQueue {
    shards: Vec<Box<dyn JobQueueApi>>,
    /// Rotating start shard for [`JobQueueApi::consume_fair`], so
    /// repeated fair pulls don't always drain shard 0's jobs first.
    fair_cursor: AtomicUsize,
}

impl ShardedQueue {
    pub fn new(shards: Vec<Box<dyn JobQueueApi>>) -> Result<Self> {
        if shards.is_empty() {
            bail!("need at least one shard");
        }
        Ok(ShardedQueue { shards, fair_cursor: AtomicUsize::new(0) })
    }

    /// A balancer over `n` [`DurableBroker`] shards, one WAL + snapshot
    /// pair per shard under `base_dir/shard-<i>/`. Because rendezvous
    /// routing is by queue name, every queue's history lives in exactly
    /// one shard directory — reopening with the same `n` recovers the
    /// whole keyspace, and each shard's log can sync/compact on its own
    /// cadence without cross-shard coordination. `opts` (sync policy,
    /// group-commit window, compaction threshold) applies per shard, so
    /// every shard gets its own group-commit leader: committers only ever
    /// share an fsync with traffic routed to the same shard.
    pub fn durable(base_dir: &Path, n: usize, opts: &DurabilityOptions) -> Result<Self> {
        if n == 0 {
            bail!("need at least one shard");
        }
        let mut shards: Vec<Box<dyn JobQueueApi>> = Vec::with_capacity(n);
        for i in 0..n {
            let dir = base_dir.join(format!("shard-{i}"));
            shards.push(Box::new(DurableBroker::open(&dir, opts.clone())?));
        }
        ShardedQueue::new(shards)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Rendezvous hash: shard with the highest weight(queue, shard) wins.
    pub fn shard_for(&self, queue: &str) -> usize {
        let mut best = (0usize, 0u64);
        for i in 0..self.num_shards() {
            let w = Self::weight(queue, i as u64);
            if w >= best.1 {
                best = (i, w);
            }
        }
        best.0
    }

    fn weight(queue: &str, shard: u64) -> u64 {
        // FNV-1a over the name, mixed with the shard id (SplitMix finale).
        let mut h = 0xcbf29ce484222325u64;
        for b in queue.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut z = h ^ shard.wrapping_mul(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    fn shard(&self, queue: &str) -> &dyn JobQueueApi {
        self.shards[self.shard_for(queue)].as_ref()
    }
}

impl QueueApi for ShardedQueue {
    fn declare(&self, queue: &str) -> Result<()> {
        self.shard(queue).declare(queue)
    }

    fn publish(&self, queue: &str, payload: &[u8]) -> Result<()> {
        self.shard(queue).publish(queue, payload)
    }

    fn publish_pri(&self, queue: &str, payload: &[u8], priority: u64) -> Result<()> {
        self.shard(queue).publish_pri(queue, payload, priority)
    }

    fn consume(&self, queue: &str, timeout: Duration) -> Result<Option<Delivery>> {
        self.shard(queue).consume(queue, timeout)
    }

    fn ack(&self, queue: &str, tag: u64) -> Result<()> {
        self.shard(queue).ack(queue, tag)
    }

    fn nack(&self, queue: &str, tag: u64) -> Result<()> {
        self.shard(queue).nack(queue, tag)
    }

    fn len(&self, queue: &str) -> Result<usize> {
        self.shard(queue).len(queue)
    }

    fn purge(&self, queue: &str) -> Result<()> {
        self.shard(queue).purge(queue)
    }

    fn stats(&self, queue: &str) -> Result<QueueStats> {
        self.shard(queue).stats(queue)
    }

    // Batched ops: a batch addresses ONE queue name, and rendezvous
    // routing is by queue name — so the whole batch lands on a single
    // shard. Delegating (instead of inheriting the single-op fallback
    // loop) preserves the backend's native batching through the balancer.

    fn publish_many(&self, queue: &str, payloads: &[&[u8]]) -> Result<()> {
        self.shard(queue).publish_many(queue, payloads)
    }

    fn consume_many(&self, queue: &str, max: usize, timeout: Duration) -> Result<Vec<Delivery>> {
        self.shard(queue).consume_many(queue, max, timeout)
    }

    fn ack_many(&self, queue: &str, tags: &[u64]) -> Result<()> {
        self.shard(queue).ack_many(queue, tags)
    }

    fn nack_many(&self, queue: &str, tags: &[u64]) -> Result<()> {
        self.shard(queue).nack_many(queue, tags)
    }
}

impl JobQueueApi for ShardedQueue {
    // Creation/insertion route by the qualified name, exactly like the
    // plain ops that settle the same messages later (see module doc).

    fn declare_job(&self, jobid: &str, queue: &str) -> Result<()> {
        self.shard(&job::qualify(jobid, queue)).declare_job(jobid, queue)
    }

    fn publish_job(&self, jobid: &str, queue: &str, payload: &[u8], priority: u64) -> Result<()> {
        self.shard(&job::qualify(jobid, queue)).publish_job(jobid, queue, payload, priority)
    }

    fn publish_many_job(&self, jobid: &str, queue: &str, payloads: &[&[u8]]) -> Result<()> {
        self.shard(&job::qualify(jobid, queue)).publish_many_job(jobid, queue, payloads)
    }

    fn consume_fair(&self, base: &str, timeout: Duration) -> Result<Option<(String, Delivery)>> {
        // Each shard runs its own deficit scheduler over the jobs whose
        // queues hash to it; the balancer rotates which shard answers
        // first and polls until the deadline, mirroring the broker's own
        // non-parking fair loop.
        let deadline = Instant::now() + timeout;
        loop {
            let start = self.fair_cursor.fetch_add(1, Ordering::Relaxed);
            for k in 0..self.num_shards() {
                let i = (start + k) % self.num_shards();
                if let Some(hit) = self.shards[i].consume_fair(base, Duration::ZERO)? {
                    return Ok(Some(hit));
                }
            }
            if Instant::now() >= deadline {
                return Ok(None);
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn list_jobs(&self) -> Result<Vec<JobInfo>> {
        // Merge per-shard rows: usage sums across shards; the quota is
        // fleet-wide policy (set_job_quota broadcasts), so the first
        // shard's copy serves for the merged row.
        let mut merged: BTreeMap<String, JobInfo> = BTreeMap::new();
        for s in &self.shards {
            for row in s.list_jobs()? {
                match merged.get_mut(&row.job) {
                    Some(m) => {
                        m.queues += row.queues;
                        m.ready_msgs += row.ready_msgs;
                        m.ready_bytes += row.ready_bytes;
                    }
                    None => {
                        merged.insert(row.job.clone(), row);
                    }
                }
            }
        }
        Ok(merged.into_values().collect())
    }

    fn set_job_quota(&self, jobid: &str, quota: JobQuota) -> Result<()> {
        // Broadcast: a job's queues spread across shards and each shard
        // admits against its LOCAL usage, so the cap bounds every shard
        // rather than the fleet-wide sum (a global cap would need
        // cross-shard coordination on every publish).
        for s in &self.shards {
            s.set_job_quota(jobid, quota)?;
        }
        Ok(())
    }

    fn remove_job(&self, jobid: &str) -> Result<u32> {
        let mut removed = 0;
        for s in &self.shards {
            removed += s.remove_job(jobid)?;
        }
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queue::broker::Broker;

    fn sharded(n: usize) -> ShardedQueue {
        ShardedQueue::new(
            (0..n)
                .map(|_| Box::new(Broker::with_default_timeout()) as Box<dyn JobQueueApi>)
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn rejects_empty() {
        assert!(ShardedQueue::new(vec![]).is_err());
    }

    #[test]
    fn routing_is_deterministic_and_spread() {
        let s = sharded(4);
        let mut counts = [0usize; 4];
        for i in 0..200 {
            let q = format!("results.map.e{}.b{}", i / 16, i % 16);
            let shard = s.shard_for(&q);
            assert_eq!(shard, s.shard_for(&q), "routing must be stable");
            counts[shard] += 1;
        }
        // All shards get a reasonable share (no pathological skew).
        for (i, c) in counts.iter().enumerate() {
            assert!(*c > 20, "shard {i} got only {c}/200 queues");
        }
    }

    #[test]
    fn adding_a_shard_remaps_a_minority() {
        let a = sharded(4);
        let b = sharded(5);
        let mut moved = 0;
        let total = 300;
        for i in 0..total {
            let q = format!("queue.{i}");
            // Rendezvous property: placements only move TO the new shard.
            let sa = a.shard_for(&q);
            let sb = b.shard_for(&q);
            if sa != sb {
                moved += 1;
                assert_eq!(sb, 4, "queue {q} moved between old shards");
            }
        }
        assert!(
            moved < total / 3,
            "adding one shard moved {moved}/{total} queues"
        );
    }

    #[test]
    fn end_to_end_through_shards() {
        let s = sharded(3);
        for q in ["tasks", "results.map.e0.b0", "results.map.e0.b1"] {
            s.declare(q).unwrap();
            s.publish_pri(q, q.as_bytes(), 1).unwrap();
        }
        for q in ["tasks", "results.map.e0.b0", "results.map.e0.b1"] {
            let d = s.consume(q, Duration::from_millis(10)).unwrap().unwrap();
            assert_eq!(d.payload, q.as_bytes());
            s.ack(q, d.tag).unwrap();
            assert_eq!(s.len(q).unwrap(), 0);
        }
    }

    #[test]
    fn batched_ops_ride_the_owning_shard() {
        let s = sharded(3);
        s.declare("grads").unwrap();
        let payloads: Vec<Vec<u8>> = (0..10u8).map(|i| vec![i]).collect();
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        s.publish_many("grads", &refs).unwrap();
        assert_eq!(s.len("grads").unwrap(), 10);
        let batch = s.consume_many("grads", 10, Duration::from_millis(10)).unwrap();
        assert_eq!(batch.len(), 10);
        for (i, d) in batch.iter().enumerate() {
            assert_eq!(d.payload, vec![i as u8]);
        }
        let tags: Vec<u64> = batch.iter().map(|d| d.tag).collect();
        s.nack_many("grads", &tags).unwrap();
        assert_eq!(s.len("grads").unwrap(), 10);
        let again = s.consume_many("grads", 10, Duration::from_millis(10)).unwrap();
        assert!(again.iter().all(|d| d.redelivered));
        s.ack_many("grads", &again.iter().map(|d| d.tag).collect::<Vec<_>>()).unwrap();
        assert_eq!(s.len("grads").unwrap(), 0);
    }

    #[test]
    fn durable_shards_recover_across_reopen() {
        use crate::queue::durability::SyncPolicy;
        use std::time::Duration as D;

        let base = std::env::temp_dir()
            .join(format!("jsdoop-shard-dur-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let opts = crate::queue::durability::DurabilityOptions {
            sync: SyncPolicy::EveryN(1),
            compact_after_bytes: u64::MAX,
            ..Default::default()
        };
        let queues = ["tasks", "results.map.e0.b0", "results.map.e0.b1", "grads"];
        {
            let s = ShardedQueue::durable(&base, 3, &opts).unwrap();
            for q in queues {
                s.declare(q).unwrap();
                s.publish(q, q.as_bytes()).unwrap();
                s.publish(q, b"second").unwrap();
            }
            // One in-flight delivery + one settled on "tasks".
            let d = s.consume("tasks", D::from_millis(10)).unwrap().unwrap();
            s.ack("tasks", d.tag).unwrap();
            let _held = s.consume("tasks", D::from_millis(10)).unwrap().unwrap();
        }
        // Same shard count => same rendezvous placement => full recovery.
        let s = ShardedQueue::durable(&base, 3, &opts).unwrap();
        for q in &queues[1..] {
            assert_eq!(s.len(q).unwrap(), 2, "queue {q} lost messages");
            let d = s.consume(q, D::from_millis(10)).unwrap().unwrap();
            assert_eq!(d.payload, q.as_bytes());
            s.ack(q, d.tag).unwrap();
        }
        // "tasks": acked head gone, in-flight "second" back and flagged.
        assert_eq!(s.len("tasks").unwrap(), 1);
        let d = s.consume("tasks", D::from_millis(10)).unwrap().unwrap();
        assert_eq!(d.payload, b"second");
        assert!(d.redelivered);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn job_ops_route_with_their_settlement_twins() {
        let s = sharded(4);
        s.declare_job("alpha", "tasks").unwrap();
        s.publish_job("alpha", "tasks", b"t0", 1).unwrap();
        // Plain ops on the qualified name land on the same shard.
        assert_eq!(s.len("alpha/tasks").unwrap(), 1);
        let d = s.consume("alpha/tasks", Duration::from_millis(10)).unwrap().unwrap();
        assert_eq!(d.payload, b"t0");
        s.ack("alpha/tasks", d.tag).unwrap();
        assert_eq!(s.len("alpha/tasks").unwrap(), 0);
    }

    #[test]
    fn fair_consume_reaches_jobs_on_every_shard() {
        let s = sharded(3);
        for jobid in ["a", "b", "c", "d", "e", "f"] {
            s.declare_job(jobid, "tasks").unwrap();
            s.publish_job(jobid, "tasks", jobid.as_bytes(), 1).unwrap();
        }
        let mut seen = Vec::new();
        while let Some((jobid, d)) = s.consume_fair("tasks", Duration::ZERO).unwrap() {
            s.ack(&job::qualify(&jobid, "tasks"), d.tag).unwrap();
            seen.push(jobid);
        }
        seen.sort();
        assert_eq!(seen, ["a", "b", "c", "d", "e", "f"]);
    }

    #[test]
    fn quota_broadcast_applies_wherever_the_queue_lands() {
        use crate::queue::job::QuotaExceeded;
        let s = sharded(3);
        s.set_job_quota("capped", JobQuota { max_ready_msgs: 1, max_ready_bytes: 0 })
            .unwrap();
        s.declare_job("capped", "tasks").unwrap();
        s.publish_job("capped", "tasks", b"one", 1).unwrap();
        let err = s.publish_job("capped", "tasks", b"two", 1).unwrap_err();
        assert!(err.downcast_ref::<QuotaExceeded>().is_some());
    }

    #[test]
    fn remove_job_and_list_jobs_span_shards() {
        let s = sharded(3);
        for q in ["tasks", "grads", "results.map.e0.b0"] {
            s.declare_job("alpha", q).unwrap();
            s.publish_job("alpha", q, b"x", 1).unwrap();
        }
        s.declare_job("beta", "tasks").unwrap();
        let rows = s.list_jobs().unwrap();
        let alpha = rows.iter().find(|r| r.job == "alpha").unwrap();
        assert_eq!(alpha.queues, 3);
        assert_eq!(alpha.ready_msgs, 3);
        assert_eq!(s.remove_job("alpha").unwrap(), 3);
        assert!(s.len("alpha/tasks").is_err(), "removed queue must be gone");
        let rows = s.list_jobs().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].job, "beta");
    }

    #[test]
    fn full_training_protocol_over_shards() {
        // The Initiator + queue ops work unchanged over the balancer.
        use crate::coordinator::initiator::setup_problem;
        use crate::coordinator::ProblemSpec;
        use crate::data::Store;
        use crate::textdata::{Corpus, Schedule};

        let s = sharded(3);
        let store = Store::new();
        let spec = ProblemSpec { schedule: Schedule::tiny(), learning_rate: 0.1 };
        let corpus = Corpus::synthetic_js(1, 2000);
        let summary = setup_problem(&s, &store, &spec, &corpus, vec![0.0; 16]).unwrap();
        assert_eq!(summary.map_tasks + summary.reduce_tasks, s.len("tasks").unwrap());
    }
}
