//! Fault & churn injection (S10, paper §II.E / §VI): volunteers join and
//! leave at will, freeze mid-task, or vanish silently. One [`FaultPlan`]
//! drives both the real threaded worker pool (volunteer::pool) and the
//! discrete-event simulator (volunteer::sim), so the same scenario can be
//! exercised at both fidelities.
//!
//! Times are seconds relative to experiment start (virtual seconds in the
//! simulator, wall seconds in real mode).

use crate::util::prng::Rng;

/// Per-worker lifecycle script.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerScript {
    /// When the volunteer opens the page (0.0 = sync-start).
    pub join_at: f64,
    /// When the volunteer closes the tab (None = stays to the end).
    pub leave_at: Option<f64>,
    /// Freeze window [start, start+duration): the worker holds its task
    /// without progress (paper: "if a volunteer freezes during the
    /// resolution of a task, the task is added back to the queue").
    pub freeze: Option<(f64, f64)>,
}

impl WorkerScript {
    pub fn steady() -> Self {
        WorkerScript { join_at: 0.0, leave_at: None, freeze: None }
    }
}

/// Coordinator-side fault: the broker (QueueServer) process dies at `at`
/// and comes back `downtime` seconds later — recovered from its WAL when
/// durability is on, empty when it is off (see volunteer::sim's
/// `durable_broker` parameter and queue/durability for the real stack).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BrokerCrash {
    pub at: f64,
    pub downtime: f64,
}

/// The whole fleet's script.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub workers: Vec<WorkerScript>,
    /// Broker kill/restart windows (sorted or not; each schedules its own
    /// crash + recovery pair).
    pub broker_crashes: Vec<BrokerCrash>,
}

impl FaultPlan {
    /// All workers present from t=0 to the end (paper: sync-start).
    pub fn sync_start(n: usize) -> Self {
        FaultPlan { workers: vec![WorkerScript::steady(); n], broker_crashes: Vec::new() }
    }

    /// Volunteers trickle in (paper classroom scenario 1: "volunteers were
    /// not connected at the same time, but gradually connected").
    /// Joins are uniform over [0, spread_secs).
    pub fn async_start(n: usize, spread_secs: f64, rng: &mut Rng) -> Self {
        let mut workers: Vec<WorkerScript> = (0..n)
            .map(|_| WorkerScript {
                join_at: rng.range_f64(0.0, spread_secs),
                leave_at: None,
                freeze: None,
            })
            .collect();
        // Someone must be first at ~0 so the experiment clock is honest.
        if let Some(first) = workers.iter_mut().min_by(|a, b| a.join_at.total_cmp(&b.join_at)) {
            first.join_at = 0.0;
        }
        FaultPlan { workers, broker_crashes: Vec::new() }
    }

    /// `leavers` workers close their tab at `at` (classroom scenario 3:
    /// "we asked 16 volunteers to close their web browsers").
    pub fn departure(n: usize, leavers: usize, at: f64) -> Self {
        let mut plan = Self::sync_start(n);
        for w in plan.workers.iter_mut().take(leavers) {
            w.leave_at = Some(at);
        }
        plan
    }

    /// Random churn: each worker independently leaves with probability
    /// `p_leave` at a uniform time in [0, horizon).
    pub fn random_churn(n: usize, p_leave: f64, horizon: f64, rng: &mut Rng) -> Self {
        let workers = (0..n)
            .map(|_| WorkerScript {
                join_at: 0.0,
                leave_at: (rng.f64() < p_leave).then(|| rng.range_f64(0.0, horizon)),
                freeze: None,
            })
            .collect();
        FaultPlan { workers, broker_crashes: Vec::new() }
    }

    /// Inject a freeze into worker `w`.
    pub fn with_freeze(mut self, w: usize, at: f64, dur: f64) -> Self {
        if let Some(ws) = self.workers.get_mut(w) {
            ws.freeze = Some((at, dur));
        }
        self
    }

    /// Kill the broker at `at`, restarting it `downtime` seconds later.
    pub fn with_broker_crash(mut self, at: f64, downtime: f64) -> Self {
        self.broker_crashes.push(BrokerCrash { at, downtime });
        self
    }

    /// Is the broker down at time t?
    pub fn broker_down_at(&self, t: f64) -> bool {
        self.broker_crashes
            .iter()
            .any(|c| c.at <= t && t < c.at + c.downtime)
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Number of workers still present at time t.
    pub fn alive_at(&self, t: f64) -> usize {
        self.workers
            .iter()
            .filter(|w| w.join_at <= t && w.leave_at.map(|l| l > t).unwrap_or(true))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_start_all_alive() {
        let p = FaultPlan::sync_start(8);
        assert_eq!(p.n_workers(), 8);
        assert_eq!(p.alive_at(0.0), 8);
        assert_eq!(p.alive_at(1e9), 8);
    }

    #[test]
    fn async_start_has_zero_first_join() {
        let mut rng = Rng::new(9);
        let p = FaultPlan::async_start(16, 60.0, &mut rng);
        let min = p.workers.iter().map(|w| w.join_at).fold(f64::MAX, f64::min);
        assert_eq!(min, 0.0);
        assert!(p.alive_at(0.0) >= 1);
        assert_eq!(p.alive_at(60.0), 16);
    }

    #[test]
    fn departure_drops_half() {
        let p = FaultPlan::departure(32, 16, 100.0);
        assert_eq!(p.alive_at(50.0), 32);
        assert_eq!(p.alive_at(150.0), 16);
    }

    #[test]
    fn churn_is_deterministic_per_seed() {
        let a = FaultPlan::random_churn(20, 0.5, 100.0, &mut Rng::new(3));
        let b = FaultPlan::random_churn(20, 0.5, 100.0, &mut Rng::new(3));
        assert_eq!(a, b);
    }

    #[test]
    fn freeze_attaches() {
        let p = FaultPlan::sync_start(2).with_freeze(1, 5.0, 10.0);
        assert_eq!(p.workers[1].freeze, Some((5.0, 10.0)));
        assert_eq!(p.workers[0].freeze, None);
    }

    #[test]
    fn broker_crash_windows() {
        let p = FaultPlan::sync_start(2)
            .with_broker_crash(10.0, 5.0)
            .with_broker_crash(30.0, 1.0);
        assert_eq!(p.broker_crashes.len(), 2);
        assert!(!p.broker_down_at(9.9));
        assert!(p.broker_down_at(10.0));
        assert!(p.broker_down_at(14.9));
        assert!(!p.broker_down_at(15.0));
        assert!(p.broker_down_at(30.5));
        // Worker lifecycles are orthogonal to broker faults.
        assert_eq!(p.alive_at(12.0), 2);
    }
}
