//! DataServer substrate (S2, paper §IV.E) — the Redis stand-in.
//!
//! JSDoop "does not care about the type of DataServer implementation ...
//! just needs to know where the data is and how it can be accessed". The
//! experiment uses it as (a) blob storage for the corpus, (b) the
//! parameter server holding the versioned NN model, and (c) the
//! synchronization primitive of §IV.G: "if the required version is not yet
//! available, the task waits for updating of the NN model" —
//! [`DataApi::wait_version`].
//!
//! [`Store`] is the in-process implementation; `queue::client::RemoteData`
//! speaks the same API over TCP.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::queue::ReadyWaker;

/// Versioned value: plain KV entries have version 0; `put_versioned`
/// stores (version, bytes) and only moves forward.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Versioned {
    pub version: u64,
    pub bytes: Vec<u8>,
}

/// The data operations JSDoop needs (CRUD + versioned blobs + waiting).
pub trait DataApi: Send + Sync {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<()>;
    fn get(&self, key: &str) -> Result<Option<Vec<u8>>>;
    fn del(&self, key: &str) -> Result<bool>;
    /// Store (version, bytes); ignored if `version` <= the stored version
    /// (idempotent against duplicate reduce executions).
    fn put_versioned(&self, key: &str, version: u64, bytes: &[u8]) -> Result<()>;
    fn get_versioned(&self, key: &str) -> Result<Option<Versioned>>;
    /// Block until `key` reaches at least `min_version` (paper §IV.G map
    /// task sync). `None` on timeout.
    fn wait_version(&self, key: &str, min_version: u64, timeout: Duration)
        -> Result<Option<Versioned>>;
    /// Atomic counter increment; returns the new value (progress metrics).
    fn incr(&self, key: &str) -> Result<u64>;
}

#[derive(Debug, Default)]
struct StoreState {
    kv: HashMap<String, Versioned>,
    counters: HashMap<String, u64>,
}

/// In-process data server.
#[derive(Default)]
pub struct Store {
    state: Mutex<StoreState>,
    changed: Condvar,
    /// Parked remote `wait_version` callers (the TCP server's readiness
    /// loop), woken one-shot on every store change — the event-loop
    /// analogue of `changed`. Store-wide rather than per-key: version
    /// waits are rare (one per parked volunteer) and a spurious wake just
    /// re-checks cheaply. Kept outside `state` so wakers (foreign code)
    /// never run under the data lock.
    waiters: Mutex<HashMap<u64, Arc<dyn ReadyWaker>>>,
    /// Reject every mutation (replica mode: a follower's DataServer must
    /// not silently accept writes that diverge from the primary).
    read_only: bool,
}

impl Store {
    pub fn new() -> Self {
        Store::default()
    }

    /// A store that refuses all mutations — hosted by a replication
    /// follower so a misdirected client gets an error, not silent
    /// divergence from the primary.
    pub fn read_only() -> Self {
        Store { read_only: true, ..Store::default() }
    }

    fn check_writable(&self, op: &str) -> Result<()> {
        if self.read_only {
            bail!(
                "data store is read-only: {op} rejected (this server mirrors \
                 a primary; promote it to serve writes)"
            );
        }
        Ok(())
    }

    /// Number of keys (admin).
    pub fn num_keys(&self) -> usize {
        self.state.lock().unwrap().kv.len()
    }

    /// Register a one-shot waker fired on the next store change (put /
    /// versioned advance / incr), keyed by `id` (re-registering replaces).
    /// Same register-THEN-try protocol as the broker's
    /// [`crate::queue::QueueService::register_waiter`]: register, then
    /// check the version nonblockingly, so a write landing in between
    /// still fires the waker.
    pub fn register_waiter(&self, id: u64, waker: Arc<dyn ReadyWaker>) {
        self.waiters.lock().unwrap().insert(id, waker);
    }

    /// Drop the waker registered under `id`, if any (racing a wake is ok).
    pub fn cancel_waiter(&self, id: u64) {
        self.waiters.lock().unwrap().remove(&id);
    }

    /// Currently-registered waiter count (the `store.waiters` gauge in
    /// the `Op::Metrics` snapshot — dead-consumer cancellation must drive
    /// this back to zero).
    pub fn waiter_count(&self) -> usize {
        self.waiters.lock().unwrap().len()
    }

    /// Fire-and-consume every registered waker (outside the state lock).
    fn wake_waiters(&self) {
        let drained: Vec<Arc<dyn ReadyWaker>> = {
            let mut w = self.waiters.lock().unwrap();
            if w.is_empty() {
                return;
            }
            w.drain().map(|(_, x)| x).collect()
        };
        for w in drained {
            w.wake();
        }
    }
}

impl DataApi for Store {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        self.check_writable("put")?;
        let mut st = self.state.lock().unwrap();
        st.kv.insert(key.to_string(), Versioned { version: 0, bytes: bytes.to_vec() });
        drop(st);
        self.changed.notify_all();
        self.wake_waiters();
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        let st = self.state.lock().unwrap();
        Ok(st.kv.get(key).map(|v| v.bytes.clone()))
    }

    fn del(&self, key: &str) -> Result<bool> {
        self.check_writable("del")?;
        let mut st = self.state.lock().unwrap();
        Ok(st.kv.remove(key).is_some())
    }

    fn put_versioned(&self, key: &str, version: u64, bytes: &[u8]) -> Result<()> {
        self.check_writable("put_versioned")?;
        let mut st = self.state.lock().unwrap();
        let advance = match st.kv.get(key) {
            Some(v) => version > v.version,
            None => true,
        };
        if advance {
            st.kv.insert(key.to_string(), Versioned { version, bytes: bytes.to_vec() });
            drop(st);
            self.changed.notify_all();
            self.wake_waiters();
        }
        Ok(())
    }

    fn get_versioned(&self, key: &str) -> Result<Option<Versioned>> {
        let st = self.state.lock().unwrap();
        Ok(st.kv.get(key).cloned())
    }

    fn wait_version(
        &self,
        key: &str,
        min_version: u64,
        timeout: Duration,
    ) -> Result<Option<Versioned>> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.kv.get(key) {
                if v.version >= min_version {
                    return Ok(Some(v.clone()));
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, _) = self.changed.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    fn incr(&self, key: &str) -> Result<u64> {
        self.check_writable("incr")?;
        let mut st = self.state.lock().unwrap();
        let c = st.counters.entry(key.to_string()).or_insert(0);
        *c += 1;
        let v = *c;
        drop(st);
        self.changed.notify_all();
        self.wake_waiters();
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn kv_crud() {
        let s = Store::new();
        assert_eq!(s.get("k").unwrap(), None);
        s.put("k", b"v").unwrap();
        assert_eq!(s.get("k").unwrap().unwrap(), b"v");
        assert!(s.del("k").unwrap());
        assert!(!s.del("k").unwrap());
        assert_eq!(s.get("k").unwrap(), None);
    }

    #[test]
    fn versioned_moves_forward_only() {
        let s = Store::new();
        s.put_versioned("m", 3, b"v3").unwrap();
        s.put_versioned("m", 2, b"v2").unwrap(); // stale duplicate: ignored
        let v = s.get_versioned("m").unwrap().unwrap();
        assert_eq!(v.version, 3);
        assert_eq!(v.bytes, b"v3");
        s.put_versioned("m", 4, b"v4").unwrap();
        assert_eq!(s.get_versioned("m").unwrap().unwrap().version, 4);
    }

    #[test]
    fn wait_version_immediate_and_timeout() {
        let s = Store::new();
        s.put_versioned("m", 5, b"x").unwrap();
        let v = s.wait_version("m", 5, Duration::from_millis(1)).unwrap();
        assert_eq!(v.unwrap().version, 5);
        let v = s.wait_version("m", 6, Duration::from_millis(10)).unwrap();
        assert!(v.is_none());
    }

    #[test]
    fn wait_version_wakes_on_put() {
        let s = Arc::new(Store::new());
        let s2 = s.clone();
        let h = std::thread::spawn(move || {
            s2.wait_version("m", 1, Duration::from_secs(5)).unwrap().unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        s.put_versioned("m", 1, b"ready").unwrap();
        let v = h.join().unwrap();
        assert_eq!(v.bytes, b"ready");
    }

    #[test]
    fn read_only_store_rejects_mutations_serves_reads() {
        let s = Store::read_only();
        assert!(s.put("k", b"v").is_err());
        assert!(s.del("k").is_err());
        assert!(s.put_versioned("m", 1, b"v").is_err());
        assert!(s.incr("c").is_err());
        // Reads stay functional (and honest: nothing was written).
        assert_eq!(s.get("k").unwrap(), None);
        assert_eq!(s.get_versioned("m").unwrap(), None);
        assert!(s
            .wait_version("m", 1, Duration::from_millis(5))
            .unwrap()
            .is_none());
    }

    #[test]
    fn incr_counts() {
        let s = Store::new();
        assert_eq!(s.incr("c").unwrap(), 1);
        assert_eq!(s.incr("c").unwrap(), 2);
        assert_eq!(s.incr("d").unwrap(), 1);
    }

    #[derive(Default)]
    struct CountWaker(std::sync::atomic::AtomicUsize);

    impl ReadyWaker for CountWaker {
        fn wake(&self) {
            self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        }
    }

    #[test]
    fn store_waiters_fire_once_per_registration() {
        let s = Store::new();
        let w = Arc::new(CountWaker::default());
        let n = |w: &CountWaker| w.0.load(std::sync::atomic::Ordering::SeqCst);
        s.register_waiter(1, w.clone());
        s.put_versioned("m", 1, b"v1").unwrap();
        assert_eq!(n(&w), 1);
        // One-shot: consumed by the wake.
        s.put_versioned("m", 2, b"v2").unwrap();
        assert_eq!(n(&w), 1);
        // A STALE versioned put changes nothing and must not wake.
        s.register_waiter(1, w.clone());
        s.put_versioned("m", 2, b"dup").unwrap();
        assert_eq!(n(&w), 1);
        // put / incr wake too (any change re-checks cheaply).
        s.put("k", b"x").unwrap();
        assert_eq!(n(&w), 2);
        s.register_waiter(1, w.clone());
        s.incr("c").unwrap();
        assert_eq!(n(&w), 3);
        // Cancelled registrations stay silent.
        s.register_waiter(1, w.clone());
        s.cancel_waiter(1);
        s.put("k", b"y").unwrap();
        assert_eq!(n(&w), 3);
    }
}
