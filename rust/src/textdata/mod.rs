//! Text workload substrate (S13, paper §V.A): vocabulary, training corpus,
//! and the deterministic sample/batch schedule.
//!
//! The paper trains on "TensorFlow.js code (compiled, 0.11.7)" — minified
//! JavaScript. That exact blob is immaterial (the paper itself says any
//! text would do); we ship a deterministic JS-like corpus generator with
//! the same character regime (printable ASCII + newlines/tabs) so every
//! run — Rust or Python, distributed or sequential — sees identical data.
//!
//! Determinism contract: sample i of epoch e is a pure function of
//! (corpus, e, i). The distributed map tasks and the sequential baseline
//! therefore consume bit-identical batches, which is what makes the
//! paper's "same loss in every configuration" row reproducible.

use anyhow::{bail, Result};

use crate::util::prng::Rng;

/// Fixed vocabulary: 0='\t', 1='\n', 2..=96 = ASCII 32..126, 97 = <unk>.
/// Matches `VOCAB = 98` in python/compile/model.py (checked at load).
pub const VOCAB: usize = 98;
const UNK: u8 = 97;

/// Char -> id. Total function: unknown bytes map to `<unk>`.
pub fn char_to_id(c: u8) -> u8 {
    match c {
        b'\t' => 0,
        b'\n' => 1,
        32..=126 => c - 32 + 2,
        _ => UNK,
    }
}

/// Id -> representative char ('?' for `<unk>`).
pub fn id_to_char(id: u8) -> u8 {
    match id {
        0 => b'\t',
        1 => b'\n',
        2..=96 => id - 2 + 32,
        _ => b'?',
    }
}

/// An encoded training corpus.
#[derive(Debug, Clone)]
pub struct Corpus {
    ids: Vec<u8>,
}

impl Corpus {
    pub fn from_text(text: &str) -> Result<Self> {
        if text.len() < 256 {
            bail!("corpus too small ({} bytes); need >= 256", text.len());
        }
        Ok(Corpus { ids: text.bytes().map(char_to_id).collect() })
    }

    pub fn from_encoded(ids: Vec<u8>) -> Result<Self> {
        if ids.len() < 256 {
            bail!("corpus too small");
        }
        if let Some(&bad) = ids.iter().find(|&&c| c as usize >= VOCAB) {
            bail!("corpus contains invalid id {bad}");
        }
        Ok(Corpus { ids })
    }

    /// Deterministic JS-like corpus (the TF.js-0.11.7 stand-in): seeded
    /// stream of function definitions, expressions, and literals with
    /// realistic character statistics.
    pub fn synthetic_js(seed: u64, target_len: usize) -> Self {
        let mut rng = Rng::new(seed);
        let mut text = String::with_capacity(target_len + 128);
        text.push_str("// jsdoop synthetic corpus (tfjs stand-in)\n'use strict';\n");
        const IDENTS: &[&str] = &[
            "tensor", "shape", "dtype", "grad", "matMul", "forward", "backward",
            "adamStep", "lstmCell", "batch", "loss", "optimizer", "weights",
            "bias", "kernel", "output", "input", "layer", "model", "train",
            "dispose", "dataSync", "softmax", "sigmoid", "tanh", "relu",
            "slice", "concat", "reshape", "transpose", "sum", "mean", "sqrt",
        ];
        const KEYWORDS: &[&str] = &[
            "function", "const", "let", "var", "return", "if", "else", "for",
            "while", "new", "this", "class", "extends", "async", "await",
        ];
        while text.len() < target_len {
            let f = IDENTS[rng.below(IDENTS.len() as u64) as usize];
            let g = IDENTS[rng.below(IDENTS.len() as u64) as usize];
            let h = IDENTS[rng.below(IDENTS.len() as u64) as usize];
            let kw = KEYWORDS[rng.below(KEYWORDS.len() as u64) as usize];
            match rng.below(6) {
                0 => {
                    text.push_str(&format!(
                        "function {f}_{n}({g}, {h}) {{\n  return {g}.{f}({h}) * {v};\n}}\n",
                        n = rng.below(1000),
                        v = rng.f64() * 4.0 - 2.0
                    ));
                }
                1 => {
                    text.push_str(&format!(
                        "const {f}{n} = {kw} === '{g}' ? {h}[{i}] : {f}.{g}();\n",
                        n = rng.below(100),
                        i = rng.below(64)
                    ));
                }
                2 => {
                    text.push_str(&format!(
                        "for (let i = 0; i < {n}; ++i) {{ {f}[i] += {g}[i] * {v}; }}\n",
                        n = rng.below(512) + 1,
                        v = rng.f64()
                    ));
                }
                3 => {
                    text.push_str(&format!(
                        "if ({f}.{g} > {v}) {{ {h}.push({{{f}: {n}, {g}: '{h}'}}); }}\n",
                        v = rng.f64() * 10.0,
                        n = rng.below(9999)
                    ));
                }
                4 => {
                    text.push_str(&format!(
                        "class {F}{n} extends {G} {{ constructor() {{ super(); this.{f} = {v}; }} }}\n",
                        F = capitalize(f),
                        G = capitalize(g),
                        n = rng.below(50),
                        v = rng.below(256)
                    ));
                }
                _ => {
                    text.push_str(&format!(
                        "\tmodule.exports.{f} = ({g}) => {g}.map(x => x * {v}).reduce((a, b) => a + b, {n});\n",
                        v = rng.f64() * 2.0,
                        n = rng.below(10)
                    ));
                }
            }
        }
        text.truncate(target_len);
        Corpus { ids: text.bytes().map(char_to_id).collect() }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn ids(&self) -> &[u8] {
        &self.ids
    }

    /// Raw bytes for DataServer storage.
    pub fn to_bytes(&self) -> Vec<u8> {
        self.ids.clone()
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        Self::from_encoded(bytes.to_vec())
    }

    /// Decode a window back to text (demo / debugging).
    pub fn decode(&self, start: usize, len: usize) -> String {
        self.ids[start..(start + len).min(self.ids.len())]
            .iter()
            .map(|&i| id_to_char(i) as char)
            .collect()
    }
}

fn capitalize(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

/// Table 2 + Table 3 parameters as one schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Schedule {
    pub seq_len: usize,            // 40
    pub batch_size: usize,         // 128
    pub minibatch_size: usize,     // 8
    pub examples_per_epoch: usize, // 2048
    pub epochs: usize,             // 5
}

impl Schedule {
    /// The paper's configuration (Tables 2-3).
    pub fn paper() -> Self {
        Schedule {
            seq_len: 40,
            batch_size: 128,
            minibatch_size: 8,
            examples_per_epoch: 2048,
            epochs: 5,
        }
    }

    /// A scaled-down schedule for fast tests.
    pub fn tiny() -> Self {
        Schedule {
            seq_len: 40,
            batch_size: 16,
            minibatch_size: 8,
            examples_per_epoch: 32,
            epochs: 1,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.batch_size == 0 || self.minibatch_size == 0 || self.seq_len == 0 {
            bail!("schedule sizes must be positive");
        }
        if self.batch_size % self.minibatch_size != 0 {
            bail!("batch_size must be a multiple of minibatch_size");
        }
        if self.examples_per_epoch % self.batch_size != 0 {
            bail!("examples_per_epoch must be a multiple of batch_size");
        }
        Ok(())
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.examples_per_epoch / self.batch_size
    }

    pub fn minibatches_per_batch(&self) -> usize {
        self.batch_size / self.minibatch_size
    }

    pub fn total_batches(&self) -> usize {
        self.epochs * self.batches_per_epoch()
    }

    pub fn total_map_tasks(&self) -> usize {
        self.total_batches() * self.minibatches_per_batch()
    }

    /// Start offset of sample `idx` of `epoch` — pure deterministic hash
    /// (replaces the TF.js example's `Math.random()` starts; same effect,
    /// reproducible).
    pub fn sample_start(&self, corpus_len: usize, epoch: usize, idx: usize) -> usize {
        let span = corpus_len - self.seq_len - 1;
        let mut h = (epoch as u64).wrapping_mul(0x9e3779b97f4a7c15) ^ (idx as u64);
        h ^= h >> 33;
        h = h.wrapping_mul(0xff51afd7ed558ccd);
        h ^= h >> 33;
        (h % span as u64) as usize
    }

    /// Materialize samples [first, first+count) of `epoch` as (x, y)
    /// arrays: x is row-major [count, seq_len] i32, y is [count] i32.
    pub fn samples(
        &self,
        corpus: &Corpus,
        epoch: usize,
        first: usize,
        count: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::with_capacity(count * self.seq_len);
        let mut y = Vec::with_capacity(count);
        for k in 0..count {
            let start = self.sample_start(corpus.len(), epoch, first + k);
            for t in 0..self.seq_len {
                x.push(corpus.ids()[start + t] as i32);
            }
            y.push(corpus.ids()[start + self.seq_len] as i32);
        }
        (x, y)
    }

    /// The 8-sample minibatch for a map task.
    pub fn minibatch(
        &self,
        corpus: &Corpus,
        epoch: usize,
        batch: usize,
        minibatch: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let first = batch * self.batch_size + minibatch * self.minibatch_size;
        self.samples(corpus, epoch, first, self.minibatch_size)
    }

    /// The full 128-sample batch (sequential baseline / eval).
    pub fn batch(&self, corpus: &Corpus, epoch: usize, batch: usize) -> (Vec<i32>, Vec<i32>) {
        self.samples(corpus, epoch, batch * self.batch_size, self.batch_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn char_mapping_roundtrips_printables() {
        for c in 32u8..=126 {
            assert_eq!(id_to_char(char_to_id(c)), c);
        }
        assert_eq!(id_to_char(char_to_id(b'\n')), b'\n');
        assert_eq!(id_to_char(char_to_id(b'\t')), b'\t');
        assert_eq!(char_to_id(200), UNK);
        assert!((char_to_id(0) as usize) < VOCAB);
    }

    #[test]
    fn synthetic_corpus_deterministic() {
        let a = Corpus::synthetic_js(7, 5000);
        let b = Corpus::synthetic_js(7, 5000);
        assert_eq!(a.ids(), b.ids());
        assert_eq!(a.len(), 5000);
        let c = Corpus::synthetic_js(8, 5000);
        assert_ne!(a.ids(), c.ids());
    }

    #[test]
    fn corpus_bytes_roundtrip() {
        let a = Corpus::synthetic_js(1, 1000);
        let b = Corpus::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.ids(), b.ids());
    }

    #[test]
    fn corpus_rejects_tiny_and_invalid() {
        assert!(Corpus::from_text("short").is_err());
        let mut ids = vec![0u8; 300];
        ids[5] = 99; // >= VOCAB
        assert!(Corpus::from_encoded(ids).is_err());
    }

    #[test]
    fn paper_schedule_counts() {
        let s = Schedule::paper();
        s.validate().unwrap();
        assert_eq!(s.batches_per_epoch(), 16);
        assert_eq!(s.minibatches_per_batch(), 16);
        assert_eq!(s.total_batches(), 80);
        assert_eq!(s.total_map_tasks(), 1280);
    }

    #[test]
    fn schedule_validation_catches_misconfig() {
        let mut s = Schedule::paper();
        s.minibatch_size = 7;
        assert!(s.validate().is_err());
        let mut s2 = Schedule::paper();
        s2.examples_per_epoch = 100;
        assert!(s2.validate().is_err());
    }

    #[test]
    fn minibatches_tile_the_batch() {
        let s = Schedule::tiny();
        let corpus = Corpus::synthetic_js(3, 4000);
        let (bx, by) = s.batch(&corpus, 0, 1);
        let k = s.minibatches_per_batch();
        let mut mx = Vec::new();
        let mut my = Vec::new();
        for m in 0..k {
            let (x, y) = s.minibatch(&corpus, 0, 1, m);
            mx.extend(x);
            my.extend(y);
        }
        assert_eq!(mx, bx);
        assert_eq!(my, by);
    }

    #[test]
    fn sample_starts_in_bounds_and_stable() {
        let s = Schedule::paper();
        let len = 100_000;
        for epoch in 0..3 {
            for idx in (0..2048).step_by(111) {
                let st = s.sample_start(len, epoch, idx);
                assert!(st + s.seq_len + 1 <= len);
                assert_eq!(st, s.sample_start(len, epoch, idx));
            }
        }
    }

    #[test]
    fn next_char_label_is_adjacent() {
        let s = Schedule::tiny();
        let corpus = Corpus::synthetic_js(5, 3000);
        let (x, y) = s.samples(&corpus, 0, 0, 1);
        let start = s.sample_start(corpus.len(), 0, 0);
        assert_eq!(x[0], corpus.ids()[start] as i32);
        assert_eq!(y[0], corpus.ids()[start + s.seq_len] as i32);
    }
}
