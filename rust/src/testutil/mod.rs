//! Mini property-testing harness (proptest is unavailable offline — see
//! DESIGN.md §Substitutions). Seeded generation + a fixed case budget +
//! failure reporting with the reproducing seed. No shrinking; cases are
//! kept small instead.

pub mod prop;
