//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs derived from a fixed master seed (override with env
//! JSDOOP_PROP_SEED to replay). Each case gets an independent [`Rng`]; on
//! failure the panic message carries the case seed for replay.

use crate::util::prng::Rng;

/// Default number of cases per property (kept moderate: several
/// properties spin up whole broker/fleet stacks per case).
pub const DEFAULT_CASES: u64 = 32;

fn master_seed() -> u64 {
    std::env::var("JSDOOP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_cafe_f00d_u64)
}

/// Run `prop` over `cases` seeded inputs. The property receives a fresh
/// deterministic [`Rng`]; return `Err(msg)` (or panic) to fail.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let master = master_seed();
    for case in 0..cases {
        let case_seed = master ^ case.wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{cases}: {msg}\n\
                 replay with JSDOOP_PROP_SEED={master} (case seed {case_seed})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 10, |_rng| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn failing_property_panics_with_seed() {
        check("boom", 5, |rng| {
            if rng.below(2) == 0 {
                Err("bad".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("collect", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("collect", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
