//! Flight recorder (S18): a zero-dependency metrics + tracing registry
//! shared by every hot layer — server event loop, broker, WAL,
//! replication follower, and volunteer agents — and exposed live over the
//! wire as `Op::Metrics` (see `queue/server/`) and on the CLI as
//! `jsdoop metrics [--watch=N --json | --prom]` / `jsdoop serve
//! --metrics_every=N`.
//!
//! # Overhead contract
//!
//! Hot paths touch ONLY process-global atomics with relaxed ordering:
//! - **counters** — monotonic `AtomicU64`s ([`inc`] / [`add`]);
//! - **gauges** — signed levels ([`gauge_add`] / [`gauge_set`]);
//! - **histograms** — fixed log2-bucket latency/size histograms
//!   ([`observe`]): bucket `b` holds values in `[2^(b-1), 2^b)` (bucket 0
//!   holds exactly 0), [`HIST_BUCKETS`] buckets total, so one observation
//!   is a `leading_zeros` + three relaxed `fetch_add`s — no locks, no
//!   allocation, no clock reads beyond what the caller already took.
//!
//! Memory is statically bounded: the whole registry is a few KB of
//! statics plus one mutex-guarded trace ring capped at [`TRACE_CAP`]
//! entries. The trace ring ([`trace`]) is for RARE structural events only
//! (WAL poison/rotation, replication re-baselines, connection reaps) —
//! never per-op paths; it takes a mutex and allocates.
//!
//! The registry is process-global because the op executor
//! (`server::execute_op`) has a fixed public signature and the layers it
//! calls into (broker, WAL, store) are shared `Arc`s — threading a
//! registry handle through every call would churn every API for no
//! isolation win (one process == one server == one registry). Tests
//! therefore assert DELTAS, not absolutes; [`reset`] exists for
//! single-threaded bench harnesses.
//!
//! # Snapshot codec
//!
//! [`snapshot`] folds the registry (plus caller-supplied per-queue rows —
//! live depth/inflight/waiter state belongs to the broker, not the
//! registry) into a [`MetricsSnapshot`], encoded as a versioned frame
//! ([`encode`] / [`decode`]) that rides `Op::Metrics`. The decoder is
//! [`BodyReader`]-audited like every other frame: all counts are bounded
//! against the input length in division form before any allocation.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;

use crate::queue::wire::{put_str, put_u32, BodyReader};

// ---------------------------------------------------------------------------
// Registry schema
// ---------------------------------------------------------------------------

/// Monotonic counters. Names (see [`COUNTER_NAMES`]) are dot-scoped by
/// layer; the enum is the hot-path handle (index into a static array).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Requests executed by the server's worker pool (all ops).
    ServerOps,
    ServerConnsAccepted,
    ServerConnsClosed,
    /// Idle connections closed by the reaper (`--idle_timeout`).
    ServerConnsReaped,
    /// Poll rounds where one connection exhausted its READ_BUDGET.
    ServerReadBudgetExhausted,
    /// Response flushes that left bytes buffered (peer slower than us).
    ServerBackpressureStalls,
    /// Blocking ops parked (waiter registered, no thread held).
    ServerParks,
    /// Accepts refused by the per-IP connection cap
    /// (`--max_conns_per_ip`).
    ServerConnsRefused,
    /// Accept-loop backoff pauses (EMFILE and friends). A climbing rate
    /// here is fd exhaustion, which is otherwise silent.
    ServerAcceptBackoffs,
    /// Waiter registrations fired by broker notify sites.
    BrokerWaiterFires,
    BrokerPurges,
    WalAppends,
    WalSyncs,
    WalRotations,
    /// Transitions INTO the poisoned state (fsync/append/rotate failure).
    WalPoisons,
    ReplPulls,
    ReplRebaselines,
    AgentMapTasks,
    AgentCombineTasks,
    AgentReduceTasks,
    /// Stale tasks handed back / swapped for the current version's work.
    AgentStaleSwaps,
    /// Corrupt (poison) payloads dropped from gradient folds.
    AgentPoisonDropped,
    /// Producer-subtree republish rounds triggered by poison/stalls.
    AgentPoisonRepublish,
    /// Async updates rejected by the staleness policy and recycled as
    /// fresh producer tasks (`--agg=async:<tau>`).
    AgentUpdatesRecycled,
}

pub const NUM_COUNTERS: usize = 24;

pub const COUNTER_NAMES: [&str; NUM_COUNTERS] = [
    "server.ops",
    "server.conns_accepted",
    "server.conns_closed",
    "server.conns_reaped",
    "server.read_budget_exhausted",
    "server.backpressure_stalls",
    "server.parks",
    "server.conns_refused",
    "server.accept_backoffs",
    "broker.waiter_fires",
    "broker.purges",
    "wal.appends",
    "wal.syncs",
    "wal.rotations",
    "wal.poisons",
    "repl.pulls",
    "repl.rebaselines",
    "agent.map_tasks",
    "agent.combine_tasks",
    "agent.reduce_tasks",
    "agent.stale_swaps",
    "agent.poison_dropped",
    "agent.poison_republish",
    "agent.updates_recycled",
];

/// Signed level gauges (current state, not totals).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    ServerConnsLive,
    ServerConnsParked,
    /// Store-side waiter registrations (WaitVersion parks), set at
    /// snapshot time by the metrics op handler.
    StoreWaiters,
    /// WAL records appended but not yet fsync-covered.
    WalUnsyncedRecords,
    /// Follower only: primary durable bytes minus applied offset.
    ReplBytesBehind,
}

pub const NUM_GAUGES: usize = 5;

pub const GAUGE_NAMES: [&str; NUM_GAUGES] = [
    "server.conns_live",
    "server.conns_parked",
    "store.waiters",
    "wal.unsynced_records",
    "repl.bytes_behind_durable",
];

/// Log2-bucket histograms. `_ns` names hold nanoseconds; the rest hold
/// plain counts (e.g. records per fsync batch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hist {
    /// Dispatch-to-worker-pickup latency (queue wait in the pool).
    ServerOpQueueWaitNs,
    /// Worker execute time (excludes queue wait and response write).
    ServerOpExecuteNs,
    /// One full event-loop round (poll + housekeeping).
    ServerPollRoundNs,
    WalAppendNs,
    WalFsyncNs,
    /// Records settled per completed fsync (group-commit batch size).
    WalSyncBatchRecords,
    ReplPullNs,
    AgentMapServiceNs,
    AgentCombineServiceNs,
    AgentReduceServiceNs,
}

pub const NUM_HISTS: usize = 10;

pub const HIST_NAMES: [&str; NUM_HISTS] = [
    "server.op_queue_wait_ns",
    "server.op_execute_ns",
    "server.poll_round_ns",
    "wal.append_ns",
    "wal.fsync_ns",
    "wal.sync_batch_records",
    "repl.pull_ns",
    "agent.map_service_ns",
    "agent.combine_service_ns",
    "agent.reduce_service_ns",
];

/// Buckets per histogram. Bucket 0 holds exactly 0; bucket `b` holds
/// `[2^(b-1), 2^b)`; the last bucket absorbs everything above (for ns
/// that is >= ~0.54 s — beyond any latency this stack should see).
pub const HIST_BUCKETS: usize = 32;

/// Trace ring capacity (oldest entries overwritten).
pub const TRACE_CAP: usize = 256;

// ---------------------------------------------------------------------------
// Storage
// ---------------------------------------------------------------------------

static COUNTERS: [AtomicU64; NUM_COUNTERS] =
    [const { AtomicU64::new(0) }; NUM_COUNTERS];
static GAUGES: [AtomicI64; NUM_GAUGES] = [const { AtomicI64::new(0) }; NUM_GAUGES];
static HIST_COUNT: [AtomicU64; NUM_HISTS] = [const { AtomicU64::new(0) }; NUM_HISTS];
static HIST_SUM: [AtomicU64; NUM_HISTS] = [const { AtomicU64::new(0) }; NUM_HISTS];
static HIST_BUCKET: [AtomicU64; NUM_HISTS * HIST_BUCKETS] =
    [const { AtomicU64::new(0) }; NUM_HISTS * HIST_BUCKETS];

/// Registry birth: trace timestamps and snapshot uptime are relative to
/// this (monotonic, process-local — wall clocks are someone else's job).
static START: Lazy<Instant> = Lazy::new(Instant::now);

static TRACES: Lazy<Mutex<VecDeque<TraceEvent>>> =
    Lazy::new(|| Mutex::new(VecDeque::with_capacity(TRACE_CAP)));

// ---------------------------------------------------------------------------
// Per-shard server stats
// ---------------------------------------------------------------------------
//
// The event loop can run as N shards (`--loop_shards=N`); SO_REUSEPORT
// balancing is by connection-tuple hash, not load, so per-shard rows are
// how a lagging or starved shard becomes visible. The registry stays
// static (overhead contract): a fixed MAX_SHARDS worth of cells, with
// only the first ACTIVE_SHARDS reported by `snapshot`.

/// Upper bound on event-loop shards (`--loop_shards` is clamped to it).
pub const MAX_SHARDS: usize = 16;

/// How many shard rows `snapshot` reports (high-water across serves in
/// this process; cleared by `reset`).
static ACTIVE_SHARDS: AtomicUsize = AtomicUsize::new(0);

static SHARD_CONNS_LIVE: [AtomicI64; MAX_SHARDS] = [const { AtomicI64::new(0) }; MAX_SHARDS];
static SHARD_CONNS_ACCEPTED: [AtomicU64; MAX_SHARDS] =
    [const { AtomicU64::new(0) }; MAX_SHARDS];
static SHARD_CONNS_REFUSED: [AtomicU64; MAX_SHARDS] =
    [const { AtomicU64::new(0) }; MAX_SHARDS];
static SHARD_POLL_SUM: [AtomicU64; MAX_SHARDS] = [const { AtomicU64::new(0) }; MAX_SHARDS];
static SHARD_POLL_BUCKET: [AtomicU64; MAX_SHARDS * HIST_BUCKETS] =
    [const { AtomicU64::new(0) }; MAX_SHARDS * HIST_BUCKETS];

/// Declare `n` shards live (called by `serve_with`); monotonic so a
/// second server in the same process never hides the first one's rows.
pub fn set_active_shards(n: usize) {
    ACTIVE_SHARDS.fetch_max(n.min(MAX_SHARDS), Ordering::Relaxed);
}

#[inline]
pub fn shard_conns_add(shard: usize, delta: i64) {
    if shard < MAX_SHARDS {
        SHARD_CONNS_LIVE[shard].fetch_add(delta, Ordering::Relaxed);
    }
}

#[inline]
pub fn shard_inc_accepted(shard: usize) {
    if shard < MAX_SHARDS {
        SHARD_CONNS_ACCEPTED[shard].fetch_add(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn shard_inc_refused(shard: usize) {
    if shard < MAX_SHARDS {
        SHARD_CONNS_REFUSED[shard].fetch_add(1, Ordering::Relaxed);
    }
}

#[inline]
pub fn shard_observe_poll_round(shard: usize, ns: u64) {
    if shard < MAX_SHARDS {
        SHARD_POLL_SUM[shard].fetch_add(ns, Ordering::Relaxed);
        SHARD_POLL_BUCKET[shard * HIST_BUCKETS + bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Hot-path API (lock-free, relaxed atomics)
// ---------------------------------------------------------------------------

#[inline]
pub fn inc(c: Counter) {
    add(c, 1);
}

#[inline]
pub fn add(c: Counter, n: u64) {
    COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
}

pub fn counter_value(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

#[inline]
pub fn gauge_add(g: Gauge, delta: i64) {
    GAUGES[g as usize].fetch_add(delta, Ordering::Relaxed);
}

#[inline]
pub fn gauge_set(g: Gauge, v: i64) {
    GAUGES[g as usize].store(v, Ordering::Relaxed);
}

pub fn gauge_value(g: Gauge) -> i64 {
    GAUGES[g as usize].load(Ordering::Relaxed)
}

/// Which bucket `v` lands in: 0 for 0, else `floor(log2 v) + 1`, capped.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Lower bound of bucket `b` (inclusive).
pub fn bucket_floor(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        1u64 << (b - 1)
    }
}

/// Record one observation (latency in ns, or a plain count).
#[inline]
pub fn observe(h: Hist, v: u64) {
    let i = h as usize;
    HIST_COUNT[i].fetch_add(1, Ordering::Relaxed);
    HIST_SUM[i].fetch_add(v, Ordering::Relaxed);
    HIST_BUCKET[i * HIST_BUCKETS + bucket_of(v)].fetch_add(1, Ordering::Relaxed);
}

/// Record the ns elapsed since `t0` (the common latency-hook shape).
#[inline]
pub fn observe_since(h: Hist, t0: Instant) {
    observe(h, t0.elapsed().as_nanos().min(u64::MAX as u128) as u64);
}

/// `(count, sum)` of a histogram — delta-based test/bench assertions.
pub fn hist_stats(h: Hist) -> (u64, u64) {
    let i = h as usize;
    (HIST_COUNT[i].load(Ordering::Relaxed), HIST_SUM[i].load(Ordering::Relaxed))
}

/// Append a structural trace event (RARE paths only — takes a mutex).
pub fn trace(kind: &'static str, detail: impl Into<String>) {
    let ev = TraceEvent {
        at_ms: START.elapsed().as_millis().min(u64::MAX as u128) as u64,
        kind: kind.to_string(),
        detail: detail.into(),
    };
    let mut ring = TRACES.lock().unwrap();
    if ring.len() == TRACE_CAP {
        ring.pop_front();
    }
    ring.push_back(ev);
}

/// Zero every counter/gauge/histogram and clear the trace ring. For
/// single-threaded bench/test harness setup only — concurrent writers
/// racing a reset see no tearing (each cell is atomic) but deltas across
/// it are meaningless.
pub fn reset() {
    for c in &COUNTERS {
        c.store(0, Ordering::Relaxed);
    }
    for g in &GAUGES {
        g.store(0, Ordering::Relaxed);
    }
    for h in HIST_COUNT.iter().chain(HIST_SUM.iter()).chain(HIST_BUCKET.iter()) {
        h.store(0, Ordering::Relaxed);
    }
    ACTIVE_SHARDS.store(0, Ordering::Relaxed);
    for g in &SHARD_CONNS_LIVE {
        g.store(0, Ordering::Relaxed);
    }
    for c in SHARD_CONNS_ACCEPTED
        .iter()
        .chain(SHARD_CONNS_REFUSED.iter())
        .chain(SHARD_POLL_SUM.iter())
        .chain(SHARD_POLL_BUCKET.iter())
    {
        c.store(0, Ordering::Relaxed);
    }
    TRACES.lock().unwrap().clear();
}

// ---------------------------------------------------------------------------
// Snapshot
// ---------------------------------------------------------------------------

/// One queue's live state at snapshot time. Filled by the metrics op
/// handler from the broker (the registry holds no per-queue state — a
/// dynamic-keyed hot-path map would need a lock the overhead contract
/// forbids).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueueMetrics {
    pub name: String,
    pub published: u64,
    pub delivered: u64,
    pub acked: u64,
    pub nacked: u64,
    pub redelivered: u64,
    /// Ready depth.
    pub ready: u64,
    /// In-flight (delivered, unACKed).
    pub unacked: u64,
    /// Parked consumer waiter registrations.
    pub waiters: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub name: String,
    pub count: u64,
    pub sum: u64,
    pub buckets: Vec<u64>,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile (bucket lower bound at the cumulative cut).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let cut = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (b, n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= cut {
                return bucket_floor(b);
            }
        }
        bucket_floor(self.buckets.len().saturating_sub(1))
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Milliseconds since registry start (process-local monotonic).
    pub at_ms: u64,
    pub kind: String,
    pub detail: String,
}

/// Everything `Op::Metrics` returns. Decoded schema-independently: names
/// ride the wire, so old clients render new servers' metrics verbatim.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub uptime_ms: u64,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<HistSnapshot>,
    pub queues: Vec<QueueMetrics>,
    pub events: Vec<TraceEvent>,
}

impl MetricsSnapshot {
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<i64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|h| h.name == name)
    }

    pub fn queue(&self, name: &str) -> Option<&QueueMetrics> {
        self.queues.iter().find(|q| q.name == name)
    }

    /// Total parked consumer waiters across queues (satellite-2 gauge:
    /// must return to zero after abrupt client disconnects).
    pub fn total_queue_waiters(&self) -> u64 {
        self.queues.iter().map(|q| q.waiters).sum()
    }

    /// Drop every queue row outside `jobid`'s namespace (the CLI's
    /// `--job=<id>` filter). `""` selects the default (unprefixed)
    /// namespace. Counters/gauges/histograms stay: they are
    /// process-global by the overhead contract.
    pub fn retain_job(&mut self, jobid: &str) {
        self.queues.retain(|q| match crate::queue::job::split(&q.name) {
            (Some(job), _) => job == jobid,
            (None, _) => jobid.is_empty(),
        });
    }

    /// Human table for `jsdoop metrics`.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("uptime: {:.1}s\n", self.uptime_ms as f64 / 1000.0));
        out.push_str("-- counters --\n");
        for (name, v) in &self.counters {
            if *v != 0 {
                out.push_str(&format!("  {name:<32} {v}\n"));
            }
        }
        out.push_str("-- gauges --\n");
        for (name, v) in &self.gauges {
            out.push_str(&format!("  {name:<32} {v}\n"));
        }
        out.push_str("-- histograms (count / mean / ~p50 / ~p99) --\n");
        for h in &self.hists {
            if h.count == 0 {
                continue;
            }
            let ns = h.name.ends_with("_ns");
            out.push_str(&format!(
                "  {:<32} {:>8}  {}  {}  {}\n",
                h.name,
                h.count,
                fmt_val(h.mean() as u64, ns),
                fmt_val(h.quantile(0.50), ns),
                fmt_val(h.quantile(0.99), ns),
            ));
        }
        out.push_str("-- queues (ready / unacked / waiters | pub / deliv / ack / nack / redeliv) --\n");
        // Rows group by job namespace: default (unprefixed) rows first,
        // exactly as a single-job deployment always printed them, then
        // one `[job <id>]` block per tenant with base queue names.
        let row = |out: &mut String, name: &str, q: &QueueMetrics| {
            out.push_str(&format!(
                "  {:<24} {:>6} {:>6} {:>4} | {} / {} / {} / {} / {}\n",
                name,
                q.ready,
                q.unacked,
                q.waiters,
                q.published,
                q.delivered,
                q.acked,
                q.nacked,
                q.redelivered,
            ));
        };
        let mut by_job: std::collections::BTreeMap<&str, Vec<&QueueMetrics>> =
            std::collections::BTreeMap::new();
        for q in &self.queues {
            match crate::queue::job::split(&q.name) {
                (None, _) => row(&mut out, &q.name, q),
                (Some(job), _) => by_job.entry(job).or_default().push(q),
            }
        }
        for (job, rows) in &by_job {
            out.push_str(&format!("  [job {job}]\n"));
            for q in rows {
                let (_, base) = crate::queue::job::split(&q.name);
                row(&mut out, &format!("  {base}"), q);
            }
        }
        if !self.events.is_empty() {
            out.push_str("-- recent events --\n");
            for e in &self.events {
                out.push_str(&format!(
                    "  +{:.1}s {} {}\n",
                    e.at_ms as f64 / 1000.0,
                    e.kind,
                    e.detail
                ));
            }
        }
        out
    }

    /// One JSON object per call (the `--metrics_every=N` stream format).
    /// Hand-rolled — the dependency budget has no serde.
    pub fn to_json_line(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push_str(&format!("{{\"uptime_ms\":{}", self.uptime_ms));
        s.push_str(",\"counters\":{");
        for (i, (name, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{}:{v}", json_str(name)));
        }
        s.push_str("},\"gauges\":{");
        for (i, (name, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{}:{v}", json_str(name)));
        }
        s.push_str("},\"hists\":{");
        for (i, h) in self.hists.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{}:{{\"count\":{},\"sum\":{},\"p50\":{},\"p99\":{}}}",
                json_str(&h.name),
                h.count,
                h.sum,
                h.quantile(0.50),
                h.quantile(0.99),
            ));
        }
        s.push_str("},\"queues\":{");
        for (i, q) in self.queues.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{}:{{\"ready\":{},\"unacked\":{},\"waiters\":{},\"published\":{},\
                 \"delivered\":{},\"acked\":{},\"nacked\":{},\"redelivered\":{}}}",
                json_str(&q.name),
                q.ready,
                q.unacked,
                q.waiters,
                q.published,
                q.delivered,
                q.acked,
                q.nacked,
                q.redelivered,
            ));
        }
        s.push_str("}}");
        s
    }

    /// Prometheus text exposition format (`text/plain; version=0.0.4`)
    /// for `jsdoop metrics --prom`. Names are `jsdoop_`-prefixed with
    /// every non-alphanumeric folded to `_`; the log2 histograms become
    /// the cumulative `le` series Prometheus requires — observations are
    /// integers and bucket `b` spans `[2^(b-1), 2^b)`, so its inclusive
    /// upper bound is `le = 2^b - 1` (bucket 0 is exactly `le = 0`), and
    /// the final absorbing bucket is the `+Inf` series. Queue rows
    /// become `queue`-labeled families; the trace ring has no scrape
    /// representation (it is a log, not a metric).
    pub fn to_prometheus(&self) -> String {
        fn name(n: &str) -> String {
            let mut s = String::with_capacity(7 + n.len());
            s.push_str("jsdoop_");
            for c in n.chars() {
                s.push(if c.is_ascii_alphanumeric() { c } else { '_' });
            }
            s
        }
        fn label(v: &str) -> String {
            let mut s = String::with_capacity(v.len());
            for c in v.chars() {
                match c {
                    '\\' => s.push_str("\\\\"),
                    '"' => s.push_str("\\\""),
                    '\n' => s.push_str("\\n"),
                    c => s.push(c),
                }
            }
            s
        }
        let mut out = String::with_capacity(4096);
        out.push_str("# TYPE jsdoop_uptime_seconds gauge\n");
        out.push_str(&format!("jsdoop_uptime_seconds {}\n", self.uptime_ms as f64 / 1000.0));
        for (n, v) in &self.counters {
            let n = name(n);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (n, v) in &self.gauges {
            let n = name(n);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for h in &self.hists {
            let n = name(&h.name);
            out.push_str(&format!("# TYPE {n} histogram\n"));
            let mut cum = 0u64;
            for (b, c) in h.buckets.iter().enumerate() {
                if b + 1 == h.buckets.len() {
                    break; // the absorbing bucket is the +Inf series
                }
                cum += c;
                let le = if b == 0 { 0 } else { (1u64 << b) - 1 };
                out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{n}_sum {}\n{n}_count {}\n", h.sum, h.count));
        }
        if !self.queues.is_empty() {
            let gauge_fams: [(&str, fn(&QueueMetrics) -> u64); 3] =
                [("ready", |q| q.ready), ("unacked", |q| q.unacked), ("waiters", |q| q.waiters)];
            let counter_fams: [(&str, fn(&QueueMetrics) -> u64); 5] = [
                ("published", |q| q.published),
                ("delivered", |q| q.delivered),
                ("acked", |q| q.acked),
                ("nacked", |q| q.nacked),
                ("redelivered", |q| q.redelivered),
            ];
            for (fam, get) in gauge_fams {
                out.push_str(&format!("# TYPE jsdoop_queue_{fam} gauge\n"));
                for q in &self.queues {
                    out.push_str(&format!(
                        "jsdoop_queue_{fam}{{queue=\"{}\"}} {}\n",
                        label(&q.name),
                        get(q)
                    ));
                }
            }
            for (fam, get) in counter_fams {
                out.push_str(&format!("# TYPE jsdoop_queue_{fam} counter\n"));
                for q in &self.queues {
                    out.push_str(&format!(
                        "jsdoop_queue_{fam}{{queue=\"{}\"}} {}\n",
                        label(&q.name),
                        get(q)
                    ));
                }
            }
        }
        out
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn fmt_val(v: u64, ns: bool) -> String {
    if !ns {
        return format!("{v:>9}");
    }
    if v >= 1_000_000_000 {
        format!("{:>8.2}s", v as f64 / 1e9)
    } else if v >= 1_000_000 {
        format!("{:>7.2}ms", v as f64 / 1e6)
    } else if v >= 1_000 {
        format!("{:>7.2}us", v as f64 / 1e3)
    } else {
        format!("{v:>7}ns")
    }
}

/// Fold the registry plus caller-supplied per-queue rows into a snapshot.
/// When event-loop shards are active their per-shard rows are appended
/// after the static schema (`server.shard<i>.*`) — the name-carrying
/// codec ships them with no version bump, and old clients render them
/// like any other row.
pub fn snapshot(queues: Vec<QueueMetrics>) -> MetricsSnapshot {
    let mut counters: Vec<(String, u64)> = COUNTER_NAMES
        .iter()
        .enumerate()
        .map(|(i, n)| (n.to_string(), COUNTERS[i].load(Ordering::Relaxed)))
        .collect();
    let mut gauges: Vec<(String, i64)> = GAUGE_NAMES
        .iter()
        .enumerate()
        .map(|(i, n)| (n.to_string(), GAUGES[i].load(Ordering::Relaxed)))
        .collect();
    let mut hists: Vec<HistSnapshot> = HIST_NAMES
        .iter()
        .enumerate()
        .map(|(i, n)| HistSnapshot {
            name: n.to_string(),
            count: HIST_COUNT[i].load(Ordering::Relaxed),
            sum: HIST_SUM[i].load(Ordering::Relaxed),
            buckets: (0..HIST_BUCKETS)
                .map(|b| HIST_BUCKET[i * HIST_BUCKETS + b].load(Ordering::Relaxed))
                .collect(),
        })
        .collect();
    let active = ACTIVE_SHARDS.load(Ordering::Relaxed).min(MAX_SHARDS);
    for i in 0..active {
        gauges.push((
            format!("server.shard{i}.conns_live"),
            SHARD_CONNS_LIVE[i].load(Ordering::Relaxed),
        ));
        counters.push((
            format!("server.shard{i}.conns_accepted"),
            SHARD_CONNS_ACCEPTED[i].load(Ordering::Relaxed),
        ));
        counters.push((
            format!("server.shard{i}.conns_refused"),
            SHARD_CONNS_REFUSED[i].load(Ordering::Relaxed),
        ));
        let buckets: Vec<u64> = (0..HIST_BUCKETS)
            .map(|b| SHARD_POLL_BUCKET[i * HIST_BUCKETS + b].load(Ordering::Relaxed))
            .collect();
        hists.push(HistSnapshot {
            name: format!("server.shard{i}.poll_round_ns"),
            count: buckets.iter().sum(),
            sum: SHARD_POLL_SUM[i].load(Ordering::Relaxed),
            buckets,
        });
    }
    let events = TRACES.lock().unwrap().iter().cloned().collect();
    MetricsSnapshot {
        uptime_ms: START.elapsed().as_millis().min(u64::MAX as u128) as u64,
        counters,
        gauges,
        hists,
        queues,
        events,
    }
}

// ---------------------------------------------------------------------------
// Wire codec (versioned; BodyReader-audited)
// ---------------------------------------------------------------------------

/// Snapshot frame magic — `u32::MAX` marks a versioned header, like the
/// broker snapshot codec.
const MET_MAGIC: u32 = u32::MAX;
/// Current codec version; decode rejects versions from the future.
const MET_VERSION: u32 = 1;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Encode for the `Op::Metrics` response body.
/// Format: `[magic u32 = MAX][version u32][uptime_ms u64]`
/// then four counted sections (`[n u32]` + per-item fields):
/// counters `[name str][v u64]`, gauges `[name str][v i64]`, histograms
/// `[name str][count u64][sum u64][nb u32][bucket u64]*`, queues
/// `[name str][8 x u64]`, events `[at_ms u64][kind str][detail str]`.
pub fn encode(snap: &MetricsSnapshot) -> Vec<u8> {
    let mut out = Vec::with_capacity(4096);
    out.extend_from_slice(&MET_MAGIC.to_le_bytes());
    out.extend_from_slice(&MET_VERSION.to_le_bytes());
    put_u64(&mut out, snap.uptime_ms);
    put_u32(&mut out, snap.counters.len() as u32);
    for (name, v) in &snap.counters {
        put_str(&mut out, name);
        put_u64(&mut out, *v);
    }
    put_u32(&mut out, snap.gauges.len() as u32);
    for (name, v) in &snap.gauges {
        put_str(&mut out, name);
        put_u64(&mut out, *v as u64);
    }
    put_u32(&mut out, snap.hists.len() as u32);
    for h in &snap.hists {
        put_str(&mut out, &h.name);
        put_u64(&mut out, h.count);
        put_u64(&mut out, h.sum);
        put_u32(&mut out, h.buckets.len() as u32);
        for b in &h.buckets {
            put_u64(&mut out, *b);
        }
    }
    put_u32(&mut out, snap.queues.len() as u32);
    for q in &snap.queues {
        put_str(&mut out, &q.name);
        for v in [
            q.published,
            q.delivered,
            q.acked,
            q.nacked,
            q.redelivered,
            q.ready,
            q.unacked,
            q.waiters,
        ] {
            put_u64(&mut out, v);
        }
    }
    put_u32(&mut out, snap.events.len() as u32);
    for e in &snap.events {
        put_u64(&mut out, e.at_ms);
        put_str(&mut out, &e.kind);
        put_str(&mut out, &e.detail);
    }
    out
}

/// Bound a claimed item count against the input size (division form —
/// `n * per_item` wraps usize on 32-bit targets; see the PR-3 audit).
fn check_count(n: usize, total: usize, per_item: usize, what: &str) -> Result<()> {
    if n > total / per_item {
        bail!("metrics snapshot {what} count {n} exceeds frame size");
    }
    Ok(())
}

/// Decode an `Op::Metrics` response body.
pub fn decode(bytes: &[u8]) -> Result<MetricsSnapshot> {
    let total = bytes.len();
    let mut r = BodyReader::new(bytes);
    let magic = r.u32().context("metrics snapshot truncated")?;
    if magic != MET_MAGIC {
        bail!("bad metrics snapshot magic {magic:#x}");
    }
    let version = r.u32()?;
    if version == 0 || version > MET_VERSION {
        bail!("metrics snapshot version {version} is newer than this binary (max {MET_VERSION})");
    }
    let uptime_ms = r.u64()?;

    let n = r.u32()? as usize;
    check_count(n, total, 2 + 8, "counter")?;
    let mut counters = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.str().context("metrics counter truncated")?.to_string();
        counters.push((name, r.u64()?));
    }

    let n = r.u32()? as usize;
    check_count(n, total, 2 + 8, "gauge")?;
    let mut gauges = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.str().context("metrics gauge truncated")?.to_string();
        gauges.push((name, r.u64()? as i64));
    }

    let n = r.u32()? as usize;
    check_count(n, total, 2 + 8 + 8 + 4, "histogram")?;
    let mut hists = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.str().context("metrics histogram truncated")?.to_string();
        let count = r.u64()?;
        let sum = r.u64()?;
        let nb = r.u32()? as usize;
        check_count(nb, total, 8, "bucket")?;
        let mut buckets = Vec::with_capacity(nb);
        for _ in 0..nb {
            buckets.push(r.u64()?);
        }
        hists.push(HistSnapshot { name, count, sum, buckets });
    }

    let n = r.u32()? as usize;
    check_count(n, total, 2 + 8 * 8, "queue")?;
    let mut queues = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.str().context("metrics queue truncated")?.to_string();
        queues.push(QueueMetrics {
            name,
            published: r.u64()?,
            delivered: r.u64()?,
            acked: r.u64()?,
            nacked: r.u64()?,
            redelivered: r.u64()?,
            ready: r.u64()?,
            unacked: r.u64()?,
            waiters: r.u64()?,
        });
    }

    let n = r.u32()? as usize;
    check_count(n, total, 8 + 2 + 2, "event")?;
    let mut events = Vec::with_capacity(n);
    for _ in 0..n {
        let at_ms = r.u64()?;
        let kind = r.str().context("metrics event truncated")?.to_string();
        let detail = r.str().context("metrics event truncated")?.to_string();
        events.push(TraceEvent { at_ms, kind, detail });
    }

    if !r.rest().is_empty() {
        bail!("metrics snapshot has trailing bytes");
    }
    Ok(MetricsSnapshot { uptime_ms, counters, gauges, hists, queues, events })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_log2() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(7), 3);
        assert_eq!(bucket_of(8), 4);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        // The last bucket absorbs everything above its floor.
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_of(1u64 << 62), HIST_BUCKETS - 1);
        // Floors invert bucket_of at the boundary.
        for b in 1..HIST_BUCKETS - 1 {
            let lo = bucket_floor(b);
            assert_eq!(bucket_of(lo), b, "floor of bucket {b}");
            assert_eq!(bucket_of(lo * 2 - 1), b, "ceiling of bucket {b}");
        }
    }

    #[test]
    fn concurrent_increments_are_conserved() {
        // The registry is process-global and other tests may touch other
        // cells concurrently, so assert a DELTA on cells only this test
        // uses with this magnitude.
        const THREADS: usize = 8;
        const PER: u64 = 10_000;
        let c0 = counter_value(Counter::AgentStaleSwaps);
        let (h0_count, h0_sum) = hist_stats(Hist::AgentReduceServiceNs);
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                std::thread::spawn(|| {
                    for i in 0..PER {
                        inc(Counter::AgentStaleSwaps);
                        observe(Hist::AgentReduceServiceNs, i % 7);
                        gauge_add(Gauge::ReplBytesBehind, 1);
                        gauge_add(Gauge::ReplBytesBehind, -1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let n = THREADS as u64 * PER;
        assert_eq!(counter_value(Counter::AgentStaleSwaps) - c0, n);
        let (h1_count, h1_sum) = hist_stats(Hist::AgentReduceServiceNs);
        assert_eq!(h1_count - h0_count, n);
        // sum of (i % 7) over 0..10_000 per thread.
        let per_thread: u64 = (0..PER).map(|i| i % 7).sum();
        assert_eq!(h1_sum - h0_sum, THREADS as u64 * per_thread);
    }

    #[test]
    fn snapshot_codec_round_trips() {
        observe(Hist::WalFsyncNs, 1500);
        inc(Counter::WalSyncs);
        trace("test.event", "hello \"world\"\n");
        let queues = vec![QueueMetrics {
            name: "tasks.q".into(),
            published: 10,
            delivered: 8,
            acked: 5,
            nacked: 1,
            redelivered: 2,
            ready: 4,
            unacked: 3,
            waiters: 2,
        }];
        let snap = snapshot(queues);
        let bytes = encode(&snap);
        let back = decode(&bytes).unwrap();
        assert_eq!(snap, back);
        assert!(back.counter("wal.syncs").unwrap() >= 1);
        assert_eq!(back.queue("tasks.q").unwrap().ready, 4);
        assert_eq!(back.total_queue_waiters(), 2);
        assert!(back.hist("wal.fsync_ns").unwrap().count >= 1);
        // Renderers don't panic and carry the load-bearing names.
        assert!(back.render_table().contains("tasks.q"));
        let json = back.to_json_line();
        assert!(json.contains("\"tasks.q\""));
        assert!(json.starts_with('{') && json.ends_with('}'));
    }

    #[test]
    fn decode_rejects_adversarial_lengths() {
        // Truncations at every prefix must error, never panic.
        let snap = snapshot(vec![QueueMetrics {
            name: "q".into(),
            published: 1,
            delivered: 1,
            acked: 1,
            nacked: 0,
            redelivered: 0,
            ready: 0,
            unacked: 0,
            waiters: 0,
        }]);
        let good = encode(&snap);
        for cut in 0..good.len().min(64) {
            assert!(decode(&good[..cut]).is_err(), "prefix {cut} decoded");
        }
        assert!(decode(&good[..good.len() - 1]).is_err());
        // Trailing garbage is rejected.
        let mut long = good.clone();
        long.push(0);
        assert!(decode(&long).is_err());
        // A hostile count claiming more items than the frame could hold
        // must be rejected BEFORE allocation (division form: a count near
        // u32::MAX would overflow `n * per_item` on 32-bit).
        let mut hostile = Vec::new();
        hostile.extend_from_slice(&MET_MAGIC.to_le_bytes());
        hostile.extend_from_slice(&MET_VERSION.to_le_bytes());
        hostile.extend_from_slice(&0u64.to_le_bytes());
        hostile.extend_from_slice(&u32::MAX.to_le_bytes()); // counter count
        let err = decode(&hostile).unwrap_err().to_string();
        assert!(err.contains("exceeds frame size"), "unexpected: {err}");
        // Future versions are rejected loudly.
        let mut future = good.clone();
        future[4..8].copy_from_slice(&99u32.to_le_bytes());
        let err = decode(&future).unwrap_err().to_string();
        assert!(err.contains("newer"), "unexpected: {err}");
        // Bad magic (a legacy/foreign frame) is rejected.
        let mut bad = good;
        bad[0..4].copy_from_slice(&7u32.to_le_bytes());
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn quantiles_walk_buckets() {
        let h = HistSnapshot {
            name: "t".into(),
            count: 100,
            sum: 0,
            buckets: {
                let mut b = vec![0u64; HIST_BUCKETS];
                b[5] = 60; // [16, 32)
                b[10] = 40; // [512, 1024)
                b
            },
        };
        assert_eq!(h.quantile(0.5), bucket_floor(5));
        assert_eq!(h.quantile(0.99), bucket_floor(10));
        let empty = HistSnapshot { name: "e".into(), count: 0, sum: 0, buckets: vec![] };
        assert_eq!(empty.quantile(0.5), 0);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn queue_rows_group_by_job_and_filter() {
        let qm = |name: &str| QueueMetrics {
            name: name.into(),
            published: 1,
            delivered: 0,
            acked: 0,
            nacked: 0,
            redelivered: 0,
            ready: 1,
            unacked: 0,
            waiters: 0,
        };
        let mut snap = snapshot(vec![
            qm("tasks"),
            qm("beta/tasks"),
            qm("alpha/tasks"),
            qm("alpha/results.map.e0.b0"),
        ]);
        let table = snap.render_table();
        assert!(table.contains("[job alpha]"));
        assert!(table.contains("[job beta]"));
        // Default-namespace rows keep their bare names, ahead of any
        // job block (single-job output shape is unchanged).
        let pos_default = table.find("\n  tasks").unwrap();
        assert!(pos_default < table.find("[job alpha]").unwrap());
        // Jobs are alphabetical regardless of row arrival order.
        assert!(table.find("[job alpha]").unwrap() < table.find("[job beta]").unwrap());

        // --job=alpha keeps only alpha's rows; globals stay.
        snap.retain_job("alpha");
        assert_eq!(snap.queues.len(), 2);
        assert!(!snap.render_table().contains("[job beta]"));

        // --job= (empty) selects the default namespace.
        let mut d = snapshot(vec![qm("tasks"), qm("alpha/tasks")]);
        d.retain_job("");
        assert_eq!(d.queues.len(), 1);
        assert_eq!(d.queues[0].name, "tasks");
    }

    #[test]
    fn prometheus_exposition_matches_golden_scrape() {
        // A hand-built snapshot so the scrape is fully deterministic:
        // 3 observations — one 0 (bucket 0), one 1 (bucket 1), one in
        // the absorbing bucket — over a 4-bucket histogram.
        let snap = MetricsSnapshot {
            uptime_ms: 1500,
            counters: vec![("server.ops".into(), 7)],
            gauges: vec![("server.shard0.conns_live".into(), 2)],
            hists: vec![HistSnapshot {
                name: "server.poll_round_ns".into(),
                count: 3,
                sum: 6,
                buckets: vec![1, 1, 0, 1],
            }],
            queues: vec![QueueMetrics {
                name: "alpha/tasks".into(),
                published: 5,
                delivered: 4,
                acked: 3,
                nacked: 0,
                redelivered: 1,
                ready: 1,
                unacked: 1,
                waiters: 2,
            }],
            events: Vec::new(),
        };
        let golden = r#"# TYPE jsdoop_uptime_seconds gauge
jsdoop_uptime_seconds 1.5
# TYPE jsdoop_server_ops counter
jsdoop_server_ops 7
# TYPE jsdoop_server_shard0_conns_live gauge
jsdoop_server_shard0_conns_live 2
# TYPE jsdoop_server_poll_round_ns histogram
jsdoop_server_poll_round_ns_bucket{le="0"} 1
jsdoop_server_poll_round_ns_bucket{le="1"} 2
jsdoop_server_poll_round_ns_bucket{le="3"} 2
jsdoop_server_poll_round_ns_bucket{le="+Inf"} 3
jsdoop_server_poll_round_ns_sum 6
jsdoop_server_poll_round_ns_count 3
# TYPE jsdoop_queue_ready gauge
jsdoop_queue_ready{queue="alpha/tasks"} 1
# TYPE jsdoop_queue_unacked gauge
jsdoop_queue_unacked{queue="alpha/tasks"} 1
# TYPE jsdoop_queue_waiters gauge
jsdoop_queue_waiters{queue="alpha/tasks"} 2
# TYPE jsdoop_queue_published counter
jsdoop_queue_published{queue="alpha/tasks"} 5
# TYPE jsdoop_queue_delivered counter
jsdoop_queue_delivered{queue="alpha/tasks"} 4
# TYPE jsdoop_queue_acked counter
jsdoop_queue_acked{queue="alpha/tasks"} 3
# TYPE jsdoop_queue_nacked counter
jsdoop_queue_nacked{queue="alpha/tasks"} 0
# TYPE jsdoop_queue_redelivered counter
jsdoop_queue_redelivered{queue="alpha/tasks"} 1
"#;
        assert_eq!(snap.to_prometheus(), golden);
    }

    #[test]
    fn prometheus_escapes_hostile_label_values() {
        let mut snap = MetricsSnapshot {
            uptime_ms: 0,
            counters: Vec::new(),
            gauges: Vec::new(),
            hists: Vec::new(),
            queues: vec![QueueMetrics {
                name: "evil\"q\\name\nx".into(),
                published: 0,
                delivered: 0,
                acked: 0,
                nacked: 0,
                redelivered: 0,
                ready: 0,
                unacked: 0,
                waiters: 0,
            }],
            events: Vec::new(),
        };
        let text = snap.to_prometheus();
        assert!(text.contains(r#"queue="evil\"q\\name\nx""#));
        // No raw newline may survive inside a label value.
        for line in text.lines() {
            assert!(!line.ends_with("evil"));
        }
        snap.queues.clear();
        assert!(!snap.to_prometheus().contains("jsdoop_queue_"));
    }

    #[test]
    fn shard_stats_ride_the_snapshot() {
        // Deltas against the last shard slot: the registry is process-
        // global and other tests run concurrently, but only this test
        // touches MAX_SHARDS-1.
        let i = MAX_SHARDS - 1;
        let before = snapshot(Vec::new());
        let acc0 = before.counter(&format!("server.shard{i}.conns_accepted")).unwrap_or(0);
        set_active_shards(MAX_SHARDS);
        set_active_shards(2); // monotonic: must not shrink
        shard_inc_accepted(i);
        shard_inc_refused(i);
        shard_conns_add(i, 3);
        shard_conns_add(i, -1);
        shard_observe_poll_round(i, 100);
        // Out-of-range shard indexes are ignored, not a panic.
        shard_inc_accepted(MAX_SHARDS);
        shard_observe_poll_round(MAX_SHARDS + 5, 1);
        let snap = snapshot(Vec::new());
        assert_eq!(
            snap.counter(&format!("server.shard{i}.conns_accepted")).unwrap() - acc0,
            1
        );
        assert!(snap.counter(&format!("server.shard{i}.conns_refused")).unwrap() >= 1);
        assert!(snap.gauge(&format!("server.shard{i}.conns_live")).is_some());
        let h = snap.hist(&format!("server.shard{i}.poll_round_ns")).unwrap();
        assert!(h.count >= 1);
        assert_eq!(h.count, h.buckets.iter().sum::<u64>());
        // The shard rows ride the existing name-carrying codec untouched.
        let back = decode(&encode(&snap)).unwrap();
        assert_eq!(back.counter(&format!("server.shard{i}.conns_accepted")),
            snap.counter(&format!("server.shard{i}.conns_accepted")));
    }

    #[test]
    fn trace_ring_is_bounded() {
        for i in 0..TRACE_CAP + 10 {
            trace("ring.test", format!("ev{i}"));
        }
        let snap = snapshot(Vec::new());
        assert!(snap.events.len() <= TRACE_CAP);
        // The newest event survived; the oldest were dropped.
        assert!(snap.events.iter().any(|e| e.detail == format!("ev{}", TRACE_CAP + 9)));
    }
}
