//! Minimal JSON parser/serializer (serde_json is unavailable offline —
//! see DESIGN.md §Substitutions). Supports the full JSON grammar minus
//! exotic number forms; plenty for `model_meta.json`, test vectors, and
//! experiment reports, all of which we produce ourselves.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name — the common path
    /// when reading our own manifests.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of f64 (test vectors).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }
}

/// Serialize (stable key order via BTreeMap; floats via shortest repr).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\n\"y\"", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.req("b").unwrap().as_str().unwrap(), "x\n\"y\"");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"o": {"p": [{"q": 1}]}}"#).unwrap();
        let q = v.req("o").unwrap().req("p").unwrap().as_arr().unwrap()[0]
            .req("q")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(q, 1);
    }
}
