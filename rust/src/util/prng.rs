//! Deterministic PRNG (the `rand` facade is unavailable offline; see
//! DESIGN.md §Substitutions). SplitMix64 for seeding + xoshiro256** for
//! the stream — the standard pairing. Every stochastic component in the
//! simulator (worker speeds, churn, service-time jitter) draws from one of
//! these so whole experiments replay bit-identically from a u64 seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (stable: hash of parent draw + tag).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method is overkill here; modulo bias
        // for n << 2^64 is irrelevant for simulation purposes.
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (simulation jitter).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with given median and sigma (heterogeneous worker speeds).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Exponential with the given mean (arrival processes).
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
