//! Small shared utilities: JSON codec, deterministic PRNG, byte helpers.

pub mod json;
pub mod prng;

/// Decode a little-endian f32 buffer (e.g. `artifacts/init_params.bin`,
/// gradient payloads on the wire).
pub fn f32_from_le_bytes(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 4 == 0, "f32 buffer length must be 4-aligned");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Encode f32s little-endian.
pub fn f32_to_le_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Format a duration in the paper's unit (minutes, 1 decimal).
pub fn fmt_minutes(seconds: f64) -> String {
    format!("{:.1}", seconds / 60.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let v = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(f32_from_le_bytes(&f32_to_le_bytes(&v)), v);
    }

    #[test]
    #[should_panic]
    fn f32_misaligned_panics() {
        f32_from_le_bytes(&[1, 2, 3]);
    }
}
