//! Model state handling on the Rust side (S12 in DESIGN.md).
//!
//! The L2 layer flattens all parameters into ONE f32 vector (layout owned
//! by `python/compile/model.py`, mirrored in `artifacts/model_meta.json`).
//! This module loads that metadata + the initial parameters, implements the
//! deterministic gradient accumulation the reduce task performs, and the
//! (de)serialization of model snapshots stored on the DataServer.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::{f32_from_le_bytes, f32_to_le_bytes};

/// Shapes + constants exported by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab: usize,
    pub hidden: usize,
    pub seq_len: usize,
    pub num_params: usize,
    pub map_batch: usize,
    pub full_batch: usize,
    pub rmsprop_rho: f64,
    pub rmsprop_eps: f64,
    pub param_layout: Vec<ParamEntry>,
    pub artifacts: Vec<(String, String)>, // (name, file)
}

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub start: usize,
    pub end: usize,
}

impl ModelMeta {
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let path = artifact_dir.join("model_meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("model_meta.json: {e}"))?;
        let num = |k: &str| -> Result<usize> {
            Ok(j.req(k)
                .map_err(|e| anyhow::anyhow!(e))?
                .as_usize()
                .context(k.to_string())?)
        };
        let fnum = |k: &str| -> Result<f64> {
            Ok(j.req(k)
                .map_err(|e| anyhow::anyhow!(e))?
                .as_f64()
                .context(k.to_string())?)
        };
        let mut param_layout = Vec::new();
        for e in j
            .req("param_layout")
            .map_err(|e| anyhow::anyhow!(e))?
            .as_arr()
            .context("param_layout")?
        {
            param_layout.push(ParamEntry {
                name: e
                    .req("name")
                    .map_err(|e| anyhow::anyhow!(e))?
                    .as_str()
                    .unwrap_or("")
                    .to_string(),
                shape: e
                    .req("shape")
                    .map_err(|e| anyhow::anyhow!(e))?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .filter_map(|v| v.as_usize())
                    .collect(),
                start: e.req("start").map_err(|e| anyhow::anyhow!(e))?.as_usize().context("start")?,
                end: e.req("end").map_err(|e| anyhow::anyhow!(e))?.as_usize().context("end")?,
            });
        }
        let mut artifacts = Vec::new();
        if let Some(m) = j.req("artifacts").map_err(|e| anyhow::anyhow!(e))?.as_obj() {
            for (name, v) in m {
                let file = v
                    .req("file")
                    .map_err(|e| anyhow::anyhow!(e))?
                    .as_str()
                    .unwrap_or("")
                    .to_string();
                artifacts.push((name.clone(), file));
            }
        }
        let meta = ModelMeta {
            vocab: num("vocab")?,
            hidden: num("hidden")?,
            seq_len: num("seq_len")?,
            num_params: num("num_params")?,
            map_batch: num("map_batch")?,
            full_batch: num("full_batch")?,
            rmsprop_rho: fnum("rmsprop_rho")?,
            rmsprop_eps: fnum("rmsprop_eps")?,
            param_layout,
            artifacts,
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Internal consistency: layout covers [0, num_params) contiguously.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for e in &self.param_layout {
            if e.start != off {
                bail!("param layout gap before {}", e.name);
            }
            let n: usize = e.shape.iter().product();
            if e.end - e.start != n {
                bail!("param {} shape/extent mismatch", e.name);
            }
            off = e.end;
        }
        if off != self.num_params {
            bail!("param layout covers {off}, expected {}", self.num_params);
        }
        Ok(())
    }

    /// Load `init_params.bin` (seed-42 initial model from aot.py).
    pub fn load_init_params(&self, artifact_dir: &Path) -> Result<Vec<f32>> {
        let bytes = std::fs::read(artifact_dir.join("init_params.bin"))
            .context("reading init_params.bin")?;
        let v = f32_from_le_bytes(&bytes);
        if v.len() != self.num_params {
            bail!("init_params.bin has {} f32, expected {}", v.len(), self.num_params);
        }
        Ok(v)
    }
}

/// A model snapshot as stored on the DataServer: version + params + RMSprop
/// second-moment state. The reduce task reads version v, writes v+1.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSnapshot {
    pub version: u64,
    pub params: Vec<f32>,
    pub ms: Vec<f32>,
}

impl ModelSnapshot {
    pub fn initial(params: Vec<f32>) -> Self {
        let n = params.len();
        ModelSnapshot { version: 0, params, ms: vec![0.0; n] }
    }

    /// Wire/storage format: [version u64 LE][n u64 LE][params f32*n][ms f32*n].
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.params.len() * 8);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        out.extend_from_slice(&f32_to_le_bytes(&self.params));
        out.extend_from_slice(&f32_to_le_bytes(&self.ms));
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 16 {
            bail!("snapshot too short");
        }
        let version = u64::from_le_bytes(bytes[0..8].try_into().unwrap());
        let n = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let need = 16 + n * 8;
        if bytes.len() != need {
            bail!("snapshot length {} != expected {}", bytes.len(), need);
        }
        let params = f32_from_le_bytes(&bytes[16..16 + n * 4]);
        let ms = f32_from_le_bytes(&bytes[16 + n * 4..]);
        Ok(ModelSnapshot { version, params, ms })
    }
}

/// Deterministic gradient accumulator for the reduce task.
///
/// The paper's reduce "downloads all calculated gradients ... accumulates
/// gradients and updates the NN model". To make the final model independent
/// of worker scheduling (Table 4: identical loss for every configuration)
/// we accumulate strictly in minibatch-index order: slot i holds minibatch
/// i's gradient, and `fold()` sums slots 0..k left-to-right — float addition
/// is not associative, so the order is part of the contract (proptested in
/// rust/tests/prop_invariants.rs).
#[derive(Debug)]
pub struct GradAccumulator {
    slots: Vec<Option<Vec<f32>>>,
}

impl GradAccumulator {
    pub fn new(num_minibatches: usize) -> Self {
        GradAccumulator { slots: (0..num_minibatches).map(|_| None).collect() }
    }

    pub fn insert(&mut self, minibatch_idx: usize, grad: Vec<f32>) -> Result<()> {
        if minibatch_idx >= self.slots.len() {
            bail!("minibatch index {minibatch_idx} out of range");
        }
        if self.slots[minibatch_idx].is_some() {
            // Duplicate delivery (at-least-once queue semantics) — first wins.
            return Ok(());
        }
        self.slots[minibatch_idx] = Some(grad);
        Ok(())
    }

    pub fn is_complete(&self) -> bool {
        self.slots.iter().all(|s| s.is_some())
    }

    pub fn missing(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.is_none().then_some(i))
            .collect()
    }

    /// Mean of the k minibatch gradients, summed in index order.
    /// (Mean — not sum — matches the sequential batch-128 gradient: each
    /// minibatch gradient is already a mean over its 8 samples, and the
    /// batch gradient is the mean of equal-sized minibatch means.)
    pub fn fold(&self) -> Result<Vec<f32>> {
        if !self.is_complete() {
            bail!("accumulator incomplete: missing {:?}", self.missing());
        }
        let k = self.slots.len();
        let n = self.slots[0].as_ref().unwrap().len();
        let mut acc = vec![0.0f32; n];
        for slot in &self.slots {
            let g = slot.as_ref().unwrap();
            if g.len() != n {
                bail!("gradient length mismatch");
            }
            for (a, b) in acc.iter_mut().zip(g.iter()) {
                *a += b;
            }
        }
        let inv = 1.0f32 / k as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_roundtrip() {
        let s = ModelSnapshot { version: 7, params: vec![1.0, -2.0], ms: vec![0.5, 0.25] };
        let b = s.to_bytes();
        assert_eq!(ModelSnapshot::from_bytes(&b).unwrap(), s);
    }

    #[test]
    fn snapshot_rejects_truncation() {
        let s = ModelSnapshot::initial(vec![1.0; 4]);
        let mut b = s.to_bytes();
        b.pop();
        assert!(ModelSnapshot::from_bytes(&b).is_err());
        assert!(ModelSnapshot::from_bytes(&[1, 2, 3]).is_err());
    }

    #[test]
    fn accumulator_order_and_mean() {
        let mut acc = GradAccumulator::new(2);
        assert!(!acc.is_complete());
        acc.insert(1, vec![2.0, 4.0]).unwrap();
        assert_eq!(acc.missing(), vec![0]);
        acc.insert(0, vec![0.0, 2.0]).unwrap();
        assert!(acc.is_complete());
        assert_eq!(acc.fold().unwrap(), vec![1.0, 3.0]);
    }

    #[test]
    fn accumulator_duplicate_first_wins() {
        let mut acc = GradAccumulator::new(1);
        acc.insert(0, vec![1.0]).unwrap();
        acc.insert(0, vec![99.0]).unwrap(); // redelivered duplicate
        assert_eq!(acc.fold().unwrap(), vec![1.0]);
    }

    #[test]
    fn accumulator_bounds() {
        let mut acc = GradAccumulator::new(1);
        assert!(acc.insert(1, vec![]).is_err());
        assert!(acc.fold().is_err());
    }
}
