//! Model state handling on the Rust side (S12 in DESIGN.md).
//!
//! The L2 layer flattens all parameters into ONE f32 vector (layout owned
//! by `python/compile/model.py`, mirrored in `artifacts/model_meta.json`).
//! This module loads that metadata + the initial parameters, implements the
//! deterministic gradient accumulation the reduce task performs, and the
//! (de)serialization of model snapshots stored on the DataServer.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::{f32_from_le_bytes, f32_to_le_bytes};

/// Shapes + constants exported by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab: usize,
    pub hidden: usize,
    pub seq_len: usize,
    pub num_params: usize,
    pub map_batch: usize,
    pub full_batch: usize,
    pub rmsprop_rho: f64,
    pub rmsprop_eps: f64,
    pub param_layout: Vec<ParamEntry>,
    pub artifacts: Vec<(String, String)>, // (name, file)
}

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub start: usize,
    pub end: usize,
}

impl ModelMeta {
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let path = artifact_dir.join("model_meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("model_meta.json: {e}"))?;
        let num = |k: &str| -> Result<usize> {
            Ok(j.req(k)
                .map_err(|e| anyhow::anyhow!(e))?
                .as_usize()
                .context(k.to_string())?)
        };
        let fnum = |k: &str| -> Result<f64> {
            Ok(j.req(k)
                .map_err(|e| anyhow::anyhow!(e))?
                .as_f64()
                .context(k.to_string())?)
        };
        let mut param_layout = Vec::new();
        for e in j
            .req("param_layout")
            .map_err(|e| anyhow::anyhow!(e))?
            .as_arr()
            .context("param_layout")?
        {
            param_layout.push(ParamEntry {
                name: e
                    .req("name")
                    .map_err(|e| anyhow::anyhow!(e))?
                    .as_str()
                    .unwrap_or("")
                    .to_string(),
                shape: e
                    .req("shape")
                    .map_err(|e| anyhow::anyhow!(e))?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .filter_map(|v| v.as_usize())
                    .collect(),
                start: e.req("start").map_err(|e| anyhow::anyhow!(e))?.as_usize().context("start")?,
                end: e.req("end").map_err(|e| anyhow::anyhow!(e))?.as_usize().context("end")?,
            });
        }
        let mut artifacts = Vec::new();
        if let Some(m) = j.req("artifacts").map_err(|e| anyhow::anyhow!(e))?.as_obj() {
            for (name, v) in m {
                let file = v
                    .req("file")
                    .map_err(|e| anyhow::anyhow!(e))?
                    .as_str()
                    .unwrap_or("")
                    .to_string();
                artifacts.push((name.clone(), file));
            }
        }
        let meta = ModelMeta {
            vocab: num("vocab")?,
            hidden: num("hidden")?,
            seq_len: num("seq_len")?,
            num_params: num("num_params")?,
            map_batch: num("map_batch")?,
            full_batch: num("full_batch")?,
            rmsprop_rho: fnum("rmsprop_rho")?,
            rmsprop_eps: fnum("rmsprop_eps")?,
            param_layout,
            artifacts,
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Internal consistency: layout covers [0, num_params) contiguously.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for e in &self.param_layout {
            if e.start != off {
                bail!("param layout gap before {}", e.name);
            }
            let n: usize = e.shape.iter().product();
            if e.end - e.start != n {
                bail!("param {} shape/extent mismatch", e.name);
            }
            off = e.end;
        }
        if off != self.num_params {
            bail!("param layout covers {off}, expected {}", self.num_params);
        }
        Ok(())
    }

    /// Load `init_params.bin` (seed-42 initial model from aot.py).
    pub fn load_init_params(&self, artifact_dir: &Path) -> Result<Vec<f32>> {
        let bytes = std::fs::read(artifact_dir.join("init_params.bin"))
            .context("reading init_params.bin")?;
        let v = f32_from_le_bytes(&bytes);
        if v.len() != self.num_params {
            bail!("init_params.bin has {} f32, expected {}", v.len(), self.num_params);
        }
        Ok(v)
    }
}

/// A model snapshot as stored on the DataServer: version + params + RMSprop
/// second-moment state. The reduce task reads version v, writes v+1.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSnapshot {
    pub version: u64,
    pub params: Vec<f32>,
    pub ms: Vec<f32>,
}

impl ModelSnapshot {
    pub fn initial(params: Vec<f32>) -> Self {
        let n = params.len();
        ModelSnapshot { version: 0, params, ms: vec![0.0; n] }
    }

    /// Wire/storage format: [version u64 LE][n u64 LE][params f32*n][ms f32*n].
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.params.len() * 8);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        out.extend_from_slice(&f32_to_le_bytes(&self.params));
        out.extend_from_slice(&f32_to_le_bytes(&self.ms));
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 16 {
            bail!("snapshot too short");
        }
        let version = u64::from_le_bytes(bytes[0..8].try_into().unwrap());
        let n64 = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        // Division form: `16 + n * 8` wraps for an adversarial count (a
        // crafted n near 2^61 even wraps 64-bit usize into a bogus pass
        // followed by an out-of-bounds slice) — same audit as the WAL's
        // decode_record and the wire codecs.
        if ((bytes.len() - 16) / 8) as u64 != n64 || (bytes.len() - 16) % 8 != 0 {
            bail!("snapshot length {} inconsistent with element count {n64}", bytes.len());
        }
        let n = n64 as usize; // == (len - 16) / 8, so it fits usize
        let params = f32_from_le_bytes(&bytes[16..16 + n * 4]);
        let ms = f32_from_le_bytes(&bytes[16 + n * 4..]);
        Ok(ModelSnapshot { version, params, ms })
    }
}

/// Magic sentinel opening a versioned async update leaf ([`ModelUpdate`]).
/// Distinct from every layout that can share a results queue: a legacy
/// leaf `GradResult` starts with a real epoch (small), and the tree
/// partial header starts with `u32::MAX` — so `u32::MAX - 1` collides
/// with neither.
pub const UPDATE_MAGIC: u32 = u32::MAX - 1;
/// Current [`ModelUpdate`] codec version; future versions are rejected,
/// never guessed at.
pub const UPDATE_VERSION: u32 = 1;

/// An async (bounded-staleness) map result: one minibatch gradient plus
/// the version of the model it was actually computed against. Under
/// `--agg=async:<tau>` maps do not wait for the batch's nominal version —
/// they compute on whatever model is current — so the update must carry
/// its true base version for the reduce's staleness check and the
/// versioned-merge rule ([`weight_by_staleness`]). Rides the same
/// magic-header style as the tree partial `GradResult` layout.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelUpdate {
    /// Model version the gradient was computed against.
    pub base_version: u64,
    pub epoch: u32,
    pub batch: u32,
    /// Leaf slot index within the batch.
    pub minibatch: u32,
    pub loss: f32,
    pub grads: Vec<f32>,
}

impl ModelUpdate {
    /// `[magic u32][codec u32][base_version u64][epoch u32][batch u32]`
    /// `[minibatch u32][loss f32][n u32][grads f32*n]` — 36 + 4n bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(36 + self.grads.len() * 4);
        out.extend_from_slice(&UPDATE_MAGIC.to_le_bytes());
        out.extend_from_slice(&UPDATE_VERSION.to_le_bytes());
        out.extend_from_slice(&self.base_version.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.batch.to_le_bytes());
        out.extend_from_slice(&self.minibatch.to_le_bytes());
        out.extend_from_slice(&self.loss.to_le_bytes());
        out.extend_from_slice(&(self.grads.len() as u32).to_le_bytes());
        out.extend_from_slice(&f32_to_le_bytes(&self.grads));
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        if b.len() < 36 {
            bail!("model update too short ({} bytes)", b.len());
        }
        let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
        if magic != UPDATE_MAGIC {
            bail!("model update magic mismatch (got {magic:#x})");
        }
        let codec = u32::from_le_bytes(b[4..8].try_into().unwrap());
        if codec != UPDATE_VERSION {
            bail!("model update codec version {codec} not supported (have {UPDATE_VERSION})");
        }
        let base_version = u64::from_le_bytes(b[8..16].try_into().unwrap());
        let epoch = u32::from_le_bytes(b[16..20].try_into().unwrap());
        let batch = u32::from_le_bytes(b[20..24].try_into().unwrap());
        let minibatch = u32::from_le_bytes(b[24..28].try_into().unwrap());
        if minibatch == u32::MAX {
            bail!("model update claims reserved slot index");
        }
        let loss = f32::from_le_bytes(b[28..32].try_into().unwrap());
        let n = u32::from_le_bytes(b[32..36].try_into().unwrap());
        // Division form: `36 + n * 4` wraps for an adversarial count —
        // same overflow audit as the snapshot codec above.
        if ((b.len() - 36) / 4) as u32 != n || (b.len() - 36) % 4 != 0 {
            bail!("model update length {} inconsistent with element count {n}", b.len());
        }
        let grads = f32_from_le_bytes(&b[36..]);
        Ok(ModelUpdate { base_version, epoch, batch, minibatch, loss, grads })
    }
}

/// Staleness weight of the versioned-merge rule: an update produced
/// against `base_version` and applied at `current_version` is scaled by
/// `1 / (1 + d)` with `d = current - base` (saturating: a base *newer*
/// than current — a racing publish — counts as fresh). `d = 0` is exactly
/// `1.0`.
pub fn staleness_weight(base_version: u64, current_version: u64) -> f32 {
    let d = current_version.saturating_sub(base_version);
    1.0f32 / (1.0f32 + d as f32)
}

/// The versioned-merge rule for bounded-staleness aggregation: scale a
/// folded gradient by [`staleness_weight`] before the optimizer step, so
/// stale gradients pull the model proportionally less the further the
/// model has moved past their base. `d = 0` is a strict no-op — not a
/// multiply by 1.0 — so the synchronous (τ=0) path stays bit-identical
/// to the unweighted fold.
pub fn weight_by_staleness(grads: &mut [f32], base_version: u64, current_version: u64) {
    let d = current_version.saturating_sub(base_version);
    if d == 0 {
        return;
    }
    let w = 1.0f32 / (1.0f32 + d as f32);
    for g in grads.iter_mut() {
        *g *= w;
    }
}

/// Deterministic gradient accumulator for the reduce and combine tasks.
///
/// The paper's reduce "downloads all calculated gradients ... accumulates
/// gradients and updates the NN model". To make the final model independent
/// of worker scheduling (Table 4: identical loss for every configuration)
/// we accumulate strictly in slot-index order: float addition is not
/// associative, so the order is part of the contract (proptested in
/// rust/tests/prop_invariants.rs).
///
/// Generalized for tree aggregation (coordinator/agg.rs): each expected
/// slot is a disjoint leaf slot-range `[lo, hi)` with a weight (the number
/// of leaf gradients folded into it). The flat reduce uses k unit ranges
/// — [`GradAccumulator::new`] — and behaves bit-identically to the
/// original single-level accumulator. Duplicate deliveries for a range
/// settle first-wins (at-least-once dedup by range); a range the plan
/// does not expect is rejected, which is how a reducer tells its own
/// inputs from a sibling combiner's.
#[derive(Debug)]
pub struct GradAccumulator {
    ranges: Vec<(u32, u32)>,
    /// Per expected range: (weight, partial-sum gradient), once received.
    slots: Vec<Option<(u32, Vec<f32>)>>,
}

impl GradAccumulator {
    /// Flat layout: `num_minibatches` unit leaf ranges.
    pub fn new(num_minibatches: usize) -> Self {
        let ranges = (0..num_minibatches as u32).map(|i| (i, i + 1)).collect();
        GradAccumulator::with_ranges(ranges).expect("unit ranges are always valid")
    }

    /// Expected input ranges in index order (must be non-empty, sorted,
    /// disjoint, and contiguous — the shape coordinator/agg.rs compiles).
    pub fn with_ranges(ranges: Vec<(u32, u32)>) -> Result<Self> {
        if ranges.is_empty() {
            bail!("accumulator needs at least one range");
        }
        let mut expect = ranges[0].0;
        for (lo, hi) in &ranges {
            if *lo != expect || hi <= lo {
                bail!("accumulator ranges must be contiguous and non-empty, got {ranges:?}");
            }
            expect = *hi;
        }
        let n = ranges.len();
        Ok(GradAccumulator { ranges, slots: (0..n).map(|_| None).collect() })
    }

    /// Does this accumulator expect exactly the range `[lo, hi)`?
    pub fn expects(&self, lo: u32, hi: u32) -> bool {
        self.ranges.binary_search(&(lo, hi)).is_ok()
    }

    /// Leaf insert: minibatch `minibatch_idx`'s raw gradient (unit range,
    /// weight 1) — the flat reduce's entry point.
    pub fn insert(&mut self, minibatch_idx: usize, grad: Vec<f32>) -> Result<()> {
        let i = minibatch_idx as u32;
        self.insert_range(i, i + 1, 1, grad)
    }

    /// Insert the partial sum covering `[lo, hi)` with `weight` folded
    /// leaves. Duplicates settle first-wins; unknown ranges and weight /
    /// length inconsistencies are rejected (the caller treats those as
    /// poison or foreign, never as fatal).
    pub fn insert_range(&mut self, lo: u32, hi: u32, weight: u32, grads: Vec<f32>) -> Result<()> {
        let Ok(i) = self.ranges.binary_search(&(lo, hi)) else {
            bail!("range [{lo}, {hi}) is not an expected input of this fold");
        };
        if weight != hi - lo {
            bail!("range [{lo}, {hi}) carries weight {weight}, expected {}", hi - lo);
        }
        if let Some(n) = self.slot_len() {
            if grads.len() != n {
                bail!("gradient length {} != {} of earlier inputs", grads.len(), n);
            }
        }
        if self.slots[i].is_some() {
            // Duplicate delivery (at-least-once queue semantics) — first wins.
            return Ok(());
        }
        self.slots[i] = Some((weight, grads));
        Ok(())
    }

    fn slot_len(&self) -> Option<usize> {
        self.slots.iter().flatten().map(|(_, g)| g.len()).next()
    }

    pub fn is_complete(&self) -> bool {
        self.slots.iter().all(|s| s.is_some())
    }

    /// Expected ranges not yet received, in index order.
    pub fn missing_ranges(&self) -> Vec<(u32, u32)> {
        self.ranges
            .iter()
            .zip(&self.slots)
            .filter_map(|(r, s)| s.is_none().then_some(*r))
            .collect()
    }

    /// Total leaf gradients this fold covers once complete.
    pub fn total_weight(&self) -> u32 {
        self.ranges.iter().map(|(lo, hi)| hi - lo).sum()
    }

    /// Partial SUM over all inputs in range order plus the covered leaf
    /// count — what a combine task publishes upward.
    pub fn fold_sum(&self) -> Result<(Vec<f32>, u32)> {
        if !self.is_complete() {
            bail!("accumulator incomplete: missing {:?}", self.missing_ranges());
        }
        let n = self.slots[0].as_ref().unwrap().1.len();
        let mut acc = vec![0.0f32; n];
        for slot in &self.slots {
            let (_, g) = slot.as_ref().unwrap();
            if g.len() != n {
                bail!("gradient length mismatch");
            }
            for (a, b) in acc.iter_mut().zip(g.iter()) {
                *a += b;
            }
        }
        Ok((acc, self.total_weight()))
    }

    /// Mean of the covered leaf gradients, summed in range-index order.
    /// (Mean — not sum — matches the sequential batch-128 gradient: each
    /// minibatch gradient is already a mean over its 8 samples, and the
    /// batch gradient is the mean of equal-sized minibatch means.) For
    /// unit ranges this is bit-identical to the pre-tree accumulator:
    /// sum slots 0..k left-to-right, multiply by `1/k as f32`.
    pub fn fold(&self) -> Result<Vec<f32>> {
        let (mut acc, weight) = self.fold_sum()?;
        let inv = 1.0f32 / weight as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_roundtrip() {
        let s = ModelSnapshot { version: 7, params: vec![1.0, -2.0], ms: vec![0.5, 0.25] };
        let b = s.to_bytes();
        assert_eq!(ModelSnapshot::from_bytes(&b).unwrap(), s);
    }

    #[test]
    fn snapshot_rejects_truncation() {
        let s = ModelSnapshot::initial(vec![1.0; 4]);
        let mut b = s.to_bytes();
        b.pop();
        assert!(ModelSnapshot::from_bytes(&b).is_err());
        assert!(ModelSnapshot::from_bytes(&[1, 2, 3]).is_err());
    }

    #[test]
    fn snapshot_rejects_adversarial_count() {
        // n = 2^61 + 1 makes the old `16 + n * 8` wrap 64-bit usize to 24
        // — the length guard "passed" and the params slice panicked out
        // of bounds. The division-form guard must reject it as an error.
        let mut b = Vec::new();
        b.extend_from_slice(&0u64.to_le_bytes()); // version
        b.extend_from_slice(&((1u64 << 61) + 1).to_le_bytes()); // n
        b.extend_from_slice(&[0u8; 8]); // 8 payload bytes -> len 24
        assert!(ModelSnapshot::from_bytes(&b).is_err());
        // u32-scale overflow claim (wraps 32-bit usize).
        let mut c = Vec::new();
        c.extend_from_slice(&0u64.to_le_bytes());
        c.extend_from_slice(&0x2000_0001u64.to_le_bytes());
        c.extend_from_slice(&[0u8; 16]);
        assert!(ModelSnapshot::from_bytes(&c).is_err());
    }

    #[test]
    fn model_update_roundtrip() {
        let u = ModelUpdate {
            base_version: 9,
            epoch: 1,
            batch: 3,
            minibatch: 7,
            loss: 0.5,
            grads: vec![1.0, -2.5, 0.0],
        };
        let b = u.to_bytes();
        assert_eq!(b.len(), 36 + 12);
        assert_eq!(ModelUpdate::from_bytes(&b).unwrap(), u);
        // Empty gradient is representable (n = 0).
        let e = ModelUpdate { grads: vec![], ..u };
        assert_eq!(ModelUpdate::from_bytes(&e.to_bytes()).unwrap(), e);
    }

    #[test]
    fn model_update_rejects_malformed() {
        let u = ModelUpdate {
            base_version: 2,
            epoch: 0,
            batch: 1,
            minibatch: 0,
            loss: 1.0,
            grads: vec![1.0, 2.0],
        };
        let good = u.to_bytes();
        // Truncation: every prefix shorter than the full frame fails.
        for cut in [0, 1, 35, good.len() - 1] {
            assert!(ModelUpdate::from_bytes(&good[..cut]).is_err(), "cut {cut}");
        }
        // Trailing bytes break the length/count consistency.
        let mut long = good.clone();
        long.push(0);
        assert!(ModelUpdate::from_bytes(&long).is_err());
        long.extend_from_slice(&[0; 3]); // a whole extra f32
        assert!(ModelUpdate::from_bytes(&long).is_err());
        // Wrong magic (a legacy leaf's epoch, or the partial header).
        let mut m = good.clone();
        m[0..4].copy_from_slice(&3u32.to_le_bytes());
        assert!(ModelUpdate::from_bytes(&m).is_err());
        m[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ModelUpdate::from_bytes(&m).is_err());
        // Future codec version is rejected, never guessed at.
        let mut v = good.clone();
        v[4..8].copy_from_slice(&2u32.to_le_bytes());
        assert!(ModelUpdate::from_bytes(&v).is_err());
        // Reserved slot index.
        let mut s = good.clone();
        s[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ModelUpdate::from_bytes(&s).is_err());
        // Adversarial count: n near 2^30 wraps `36 + n * 4` on 32-bit
        // usize; the division form must reject it as an error.
        let mut a = good.clone();
        a[32..36].copy_from_slice(&0x4000_0001u32.to_le_bytes());
        assert!(ModelUpdate::from_bytes(&a).is_err());
    }

    #[test]
    fn staleness_weight_merge_rule() {
        assert_eq!(staleness_weight(5, 5), 1.0);
        assert_eq!(staleness_weight(5, 6), 0.5);
        assert_eq!(staleness_weight(5, 8), 0.25);
        // Racing publish (base newer than current) counts as fresh.
        assert_eq!(staleness_weight(7, 5), 1.0);
        // d = 0 is a strict no-op: bits untouched, signed zero included.
        let mut g = vec![1.5, -0.0, f32::MIN_POSITIVE];
        let orig: Vec<u32> = g.iter().map(|x| x.to_bits()).collect();
        weight_by_staleness(&mut g, 3, 3);
        assert_eq!(g.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), orig);
        // d = 1 halves exactly (dyadic weight).
        let mut h = vec![2.0, -6.0];
        weight_by_staleness(&mut h, 3, 4);
        assert_eq!(h, vec![1.0, -3.0]);
    }

    #[test]
    fn accumulator_order_and_mean() {
        let mut acc = GradAccumulator::new(2);
        assert!(!acc.is_complete());
        acc.insert(1, vec![2.0, 4.0]).unwrap();
        assert_eq!(acc.missing_ranges(), vec![(0, 1)]);
        acc.insert(0, vec![0.0, 2.0]).unwrap();
        assert!(acc.is_complete());
        assert_eq!(acc.fold().unwrap(), vec![1.0, 3.0]);
    }

    #[test]
    fn accumulator_duplicate_first_wins() {
        let mut acc = GradAccumulator::new(1);
        acc.insert(0, vec![1.0]).unwrap();
        acc.insert(0, vec![99.0]).unwrap(); // redelivered duplicate
        assert_eq!(acc.fold().unwrap(), vec![1.0]);
    }

    #[test]
    fn accumulator_bounds() {
        let mut acc = GradAccumulator::new(1);
        assert!(acc.insert(1, vec![]).is_err());
        assert!(acc.fold().is_err());
    }

    #[test]
    fn accumulator_weighted_ranges() {
        // A tree reduce folding two fanin-2 partials over k=4 leaves.
        let mut acc = GradAccumulator::with_ranges(vec![(0, 2), (2, 4)]).unwrap();
        assert!(acc.expects(0, 2));
        assert!(!acc.expects(0, 1));
        assert!(!acc.expects(1, 3));
        // Foreign / malformed inputs are rejected, not folded.
        assert!(acc.insert_range(0, 1, 1, vec![9.0]).is_err());
        assert!(acc.insert_range(0, 2, 1, vec![9.0]).is_err()); // bad weight
        acc.insert_range(2, 4, 2, vec![6.0, 2.0]).unwrap();
        assert_eq!(acc.missing_ranges(), vec![(0, 2)]);
        // Length mismatch against earlier inputs is rejected (poison).
        assert!(acc.insert_range(0, 2, 2, vec![1.0]).is_err());
        acc.insert_range(0, 2, 2, vec![2.0, 2.0]).unwrap();
        // Duplicate partial: first wins.
        acc.insert_range(0, 2, 2, vec![99.0, 99.0]).unwrap();
        assert_eq!(acc.total_weight(), 4);
        let (sum, w) = acc.fold_sum().unwrap();
        assert_eq!((sum, w), (vec![8.0, 4.0], 4));
        assert_eq!(acc.fold().unwrap(), vec![2.0, 1.0]);
    }

    #[test]
    fn accumulator_rejects_bad_range_sets() {
        assert!(GradAccumulator::with_ranges(vec![]).is_err());
        assert!(GradAccumulator::with_ranges(vec![(0, 2), (3, 4)]).is_err()); // gap
        assert!(GradAccumulator::with_ranges(vec![(0, 2), (1, 3)]).is_err()); // overlap
        assert!(GradAccumulator::with_ranges(vec![(2, 2)]).is_err()); // empty
        // Non-zero start is fine: a combine node's children mid-batch.
        let acc = GradAccumulator::with_ranges(vec![(4, 6), (6, 8)]).unwrap();
        assert_eq!(acc.total_weight(), 4);
    }
}
