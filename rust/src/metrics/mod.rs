//! Metrics & reporting (S14): task timelines (Fig 7), runtime/speedup/
//! efficiency aggregation (Figs 4-6, 8; Table 4), CSV + ASCII renderers
//! used by the bench harness.
//!
//! The speedup/efficiency definitions follow Foster (the paper's [64]):
//! *relative* speedup uses the 1-worker distributed runtime as baseline;
//! *absolute* speedup uses the sequential algorithm's runtime.

use std::fmt::Write as _;
use std::sync::Mutex;

/// What a worker was doing (Fig 7 legend: Compute = map/gradient,
/// Accumulate = reduce/update).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Compute,
    Accumulate,
}

impl SpanKind {
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Accumulate => "accumulate",
        }
    }
}

/// One task execution on one worker, in experiment-relative seconds
/// ("from the moment that a task is received to the time the task is
/// completed" — paper Fig 7 caption).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub worker: usize,
    pub kind: SpanKind,
    pub start: f64,
    pub end: f64,
}

/// Thread-safe span collector.
#[derive(Debug, Default)]
pub struct Timeline {
    spans: Mutex<Vec<Span>>,
}

impl Timeline {
    pub fn new() -> Self {
        Timeline::default()
    }

    pub fn record(&self, span: Span) {
        debug_assert!(span.end >= span.start);
        self.spans.lock().unwrap().push(span);
    }

    pub fn spans(&self) -> Vec<Span> {
        let mut v = self.spans.lock().unwrap().clone();
        v.sort_by(|a, b| a.start.total_cmp(&b.start));
        v
    }

    pub fn is_empty(&self) -> bool {
        self.spans.lock().unwrap().is_empty()
    }

    /// Experiment makespan: max end over spans (Fig 4's "parallel runtime":
    /// first start is 0 by construction).
    pub fn makespan(&self) -> f64 {
        self.spans
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.end)
            .fold(0.0, f64::max)
    }

    /// Fraction of busy time spent computing vs accumulating, per worker.
    pub fn busy_secs(&self, worker: usize) -> f64 {
        self.spans
            .lock()
            .unwrap()
            .iter()
            .filter(|s| s.worker == worker)
            .map(|s| s.end - s.start)
            .sum()
    }

    /// CSV: worker,kind,start,end (Fig 7 data file).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("worker,kind,start,end\n");
        for s in self.spans() {
            let _ = writeln!(out, "{},{},{:.6},{:.6}", s.worker, s.kind.label(), s.start, s.end);
        }
        out
    }

    /// ASCII Gantt chart (Fig 7): one row per worker, '▒' compute,
    /// '█' accumulate, '·' idle.
    pub fn render_gantt(&self, width: usize) -> String {
        let spans = self.spans();
        if spans.is_empty() {
            return String::from("(empty timeline)\n");
        }
        let t_end = self.makespan().max(1e-9);
        let n_workers = spans.iter().map(|s| s.worker).max().unwrap() + 1;
        let mut grid = vec![vec!['·'; width]; n_workers];
        for s in &spans {
            let a = ((s.start / t_end) * width as f64) as usize;
            let b = (((s.end / t_end) * width as f64).ceil() as usize).min(width);
            let ch = match s.kind {
                SpanKind::Compute => '▒',
                SpanKind::Accumulate => '█',
            };
            for cell in grid[s.worker].iter_mut().take(b).skip(a.min(width)) {
                // Accumulate wins rendering conflicts (it is rarer).
                if *cell != '█' {
                    *cell = ch;
                }
            }
        }
        let mut out = String::new();
        let _ = writeln!(
            out,
            "timeline 0 .. {:.1}s  (▒ compute, █ accumulate, · idle)",
            t_end
        );
        for (w, row) in grid.iter().enumerate() {
            let _ = writeln!(out, "w{:02} |{}|", w, row.iter().collect::<String>());
        }
        out
    }
}

/// One experiment outcome (a row of Table 4).
#[derive(Debug, Clone, PartialEq)]
pub struct RunResult {
    pub system: String,
    pub workers: usize,
    pub runtime_secs: f64,
    pub final_loss: Option<f64>,
}

/// speedup = t_ref / t (Foster). Caller picks the reference (relative vs
/// absolute — see module docs).
pub fn speedup(t_ref: f64, t: f64) -> f64 {
    t_ref / t
}

/// efficiency = speedup / workers.
pub fn efficiency(t_ref: f64, t: f64, workers: usize) -> f64 {
    speedup(t_ref, t) / workers as f64
}

/// Render Table 4: System | Workers | Runtime (min) | Loss.
pub fn render_table4(rows: &[RunResult]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "| {:<30} | {:>7} | {:>13} | {:>5} |",
        "System", "Workers", "Runtime (min)", "Loss"
    );
    let _ = writeln!(
        out,
        "|{}|{}|{}|{}|",
        "-".repeat(32),
        "-".repeat(9),
        "-".repeat(15),
        "-".repeat(7)
    );
    for r in rows {
        let loss = r
            .final_loss
            .map(|l| format!("{l:.1}"))
            .unwrap_or_else(|| "-".into());
        let _ = writeln!(
            out,
            "| {:<30} | {:>7} | {:>13.1} | {:>5} |",
            r.system,
            r.workers,
            r.runtime_secs / 60.0,
            loss
        );
    }
    out
}

/// Render an x/y series with an ideal line as an ASCII chart + data table
/// (Figs 4, 5, 6, 8). `points` are (x = workers, y); `ideal` maps x -> y.
pub fn render_series(
    title: &str,
    ylabel: &str,
    points: &[(usize, f64)],
    ideal: impl Fn(usize) -> f64,
) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    let _ = writeln!(out, "{:>8} | {:>12} | {:>12}", "workers", ylabel, "ideal");
    for (x, y) in points {
        let _ = writeln!(out, "{x:>8} | {y:>12.3} | {:>12.3}", ideal(*x));
    }
    // Log-x bar chart of measured vs ideal.
    let ymax = points
        .iter()
        .map(|(x, y)| y.max(ideal(*x)))
        .fold(0.0, f64::max)
        .max(1e-9);
    const W: usize = 48;
    for (x, y) in points {
        let bar = ((*y / ymax) * W as f64).round() as usize;
        let id = ((ideal(*x) / ymax) * W as f64).round() as usize;
        let mut row: Vec<char> = vec![' '; W + 1];
        for c in row.iter_mut().take(bar.min(W)) {
            *c = '#';
        }
        if id <= W {
            row[id] = '|';
        }
        let _ = writeln!(out, "{:>6}  [{}]", x, row.into_iter().collect::<String>());
    }
    let _ = writeln!(out, "        ('#' measured, '|' ideal)");
    out
}

/// CSV for a series: workers,value,ideal.
pub fn series_csv(points: &[(usize, f64)], ideal: impl Fn(usize) -> f64) -> String {
    let mut out = String::from("workers,value,ideal\n");
    for (x, y) in points {
        let _ = writeln!(out, "{x},{y:.6},{:.6}", ideal(*x));
    }
    out
}

// --- machine-readable bench results (BENCH_<target>.json) -------------------

/// One measured operation from a bench target. Collected alongside the
/// human-readable prints and emitted as `BENCH_<target>.json` so CI can
/// archive a perf trajectory across commits.
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub op: String,
    pub iters: u32,
    pub ns_per_op: f64,
    /// Throughput ratio vs. a named baseline in the same run (e.g. the
    /// batched path vs. the single-op loop), when one applies.
    pub speedup: Option<f64>,
}

/// Serialize rows as a JSON array (one object per measured op).
pub fn bench_json_string(rows: &[BenchRow]) -> String {
    use crate::util::json::Json;
    use std::collections::BTreeMap;

    let entries: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("op".to_string(), Json::Str(r.op.clone()));
            m.insert("iters".to_string(), Json::Num(r.iters as f64));
            m.insert("ns_per_op".to_string(), Json::Num(r.ns_per_op));
            m.insert(
                "speedup".to_string(),
                match r.speedup {
                    Some(s) => Json::Num(s),
                    None => Json::Null,
                },
            );
            Json::Obj(m)
        })
        .collect();
    format!("{}\n", Json::Arr(entries))
}

/// Serialize rows as `BENCH_<target>.json` into `$BENCH_JSON_DIR` (or the
/// working directory) and return the path written. The env lookup happens
/// here, in the bench binaries' single-threaded context — library tests
/// use [`bench_json_string`] directly.
pub fn write_bench_json(target: &str, rows: &[BenchRow]) -> std::io::Result<std::path::PathBuf> {
    let dir = std::env::var("BENCH_JSON_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    let path = dir.join(format!("BENCH_{target}.json"));
    std::fs::write(&path, bench_json_string(rows))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_makespan_and_busy() {
        let t = Timeline::new();
        t.record(Span { worker: 0, kind: SpanKind::Compute, start: 0.0, end: 2.0 });
        t.record(Span { worker: 1, kind: SpanKind::Accumulate, start: 1.0, end: 4.0 });
        t.record(Span { worker: 0, kind: SpanKind::Compute, start: 2.0, end: 3.0 });
        assert_eq!(t.makespan(), 4.0);
        assert_eq!(t.busy_secs(0), 3.0);
        assert_eq!(t.busy_secs(1), 3.0);
    }

    #[test]
    fn timeline_csv_sorted() {
        let t = Timeline::new();
        t.record(Span { worker: 1, kind: SpanKind::Compute, start: 5.0, end: 6.0 });
        t.record(Span { worker: 0, kind: SpanKind::Accumulate, start: 1.0, end: 2.0 });
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "worker,kind,start,end");
        assert!(lines[1].starts_with("0,accumulate,1.0"));
        assert!(lines[2].starts_with("1,compute,5.0"));
    }

    #[test]
    fn gantt_renders_rows() {
        let t = Timeline::new();
        t.record(Span { worker: 0, kind: SpanKind::Compute, start: 0.0, end: 10.0 });
        t.record(Span { worker: 1, kind: SpanKind::Accumulate, start: 5.0, end: 10.0 });
        let g = t.render_gantt(20);
        assert!(g.contains("w00 |"));
        assert!(g.contains("w01 |"));
        assert!(g.contains('▒'));
        assert!(g.contains('█'));
    }

    #[test]
    fn speedup_efficiency() {
        assert_eq!(speedup(100.0, 25.0), 4.0);
        assert_eq!(efficiency(100.0, 25.0, 4), 1.0);
        assert!(efficiency(100.0, 25.0, 8) < 1.0);
    }

    #[test]
    fn table_renders() {
        let rows = vec![
            RunResult {
                system: "JSDoop-cluster".into(),
                workers: 1,
                runtime_secs: 10626.0,
                final_loss: Some(4.6),
            },
            RunResult {
                system: "TFJS-Sequential-128".into(),
                workers: 1,
                runtime_secs: 54.0,
                final_loss: None,
            },
        ];
        let t = render_table4(&rows);
        assert!(t.contains("JSDoop-cluster"));
        assert!(t.contains("177.1"));
        assert!(t.contains("4.6"));
    }

    #[test]
    fn bench_json_roundtrips() {
        let rows = vec![
            BenchRow { op: "publish".into(), iters: 100, ns_per_op: 412.5, speedup: None },
            BenchRow { op: "batched".into(), iters: 50, ns_per_op: 40.0, speedup: Some(10.3) },
        ];
        let text = bench_json_string(&rows);
        let v = crate::util::json::Json::parse(text.trim()).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].req("op").unwrap().as_str().unwrap(), "publish");
        assert_eq!(arr[0].req("iters").unwrap().as_usize().unwrap(), 100);
        assert_eq!(arr[1].req("speedup").unwrap().as_f64().unwrap(), 10.3);
        assert_eq!(arr[0].req("speedup").unwrap(), &crate::util::json::Json::Null);
    }

    #[test]
    fn series_renders() {
        let pts = vec![(1, 1.0), (2, 2.2), (4, 4.5)];
        let s = render_series("fig5", "speedup", &pts, |w| w as f64);
        assert!(s.contains("fig5"));
        assert!(s.contains("4.500"));
        let csv = series_csv(&pts, |w| w as f64);
        assert!(csv.contains("4,4.500000,4.000000"));
    }
}
