//! Configuration system (S15): typed experiment config with defaults
//! matching the paper's Tables 2-3, loadable from a `key = value` file and
//! overridable with `--key=value` CLI flags (in that precedence order).
//!
//! Example file (see `examples/configs/paper.conf`):
//! ```text
//! # training
//! batch_size = 128
//! epochs = 5
//! learning_rate = 0.1
//! workers = 16
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::textdata::Schedule;

/// Everything a run needs. `Default` = the paper's configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    // Table 2
    pub batch_size: usize,
    pub examples_per_epoch: usize,
    pub learning_rate: f32,
    pub epochs: usize,
    pub seq_len: usize,
    // Table 3
    pub minibatch_size: usize,
    // Topology / runtime
    /// Aggregation topology: "flat" (paper-faithful single reducer,
    /// default) or "tree:<fanin>" (hierarchical partial sums — see
    /// coordinator/agg.rs). Applies to `train`, `init`, and `sim`.
    pub agg: String,
    pub workers: usize,
    pub queue_addr: Option<String>, // None = in-process broker
    pub data_addr: Option<String>,  // None = in-process store
    pub artifact_dir: PathBuf,
    pub visibility_timeout_secs: f64,
    pub task_poll_timeout_secs: f64,
    // Durability (queue/durability): None = plain in-memory broker.
    pub durability_dir: Option<PathBuf>,
    /// WAL sync cadence: "never" | "every=N" | "always".
    pub sync_policy: String,
    /// Snapshot-compact the WAL once a segment passes this many bytes.
    pub wal_compact_bytes: u64,
    /// Group-commit window in microseconds: how long the elected WAL
    /// sync leader waits before fsyncing so more committers batch into
    /// the same sync. 0 (default) = sync immediately.
    pub wal_group_window_us: u64,
    // Replication (queue/durability/replication).
    /// Primary address to mirror: `jsdoop serve --replicate-from=ADDR`
    /// runs as a READ-ONLY follower pulling the primary's WAL into
    /// `durability_dir` (required). Mutating ops are rejected until the
    /// mirror is promoted.
    pub replicate_from: Option<String>,
    /// Promote a follower's mirror directory: clears its replica marker
    /// so `durability_dir` recovers and serves as a primary. Bare flag
    /// form `--promote` works (it parses as `--promote=true`).
    pub promote: bool,
    /// Follower poll interval (ms) when caught up with the primary.
    pub repl_poll_ms: u64,
    // Server event loop (queue/server).
    /// Worker threads executing decoded ops in the TCP server's event
    /// loop (0 = one per CPU, capped at 8). Workers never block inside an
    /// op, so a handful covers thousands of connections.
    pub server_workers: usize,
    /// Cap on concurrently accepted server connections; excess connects
    /// wait in the OS backlog until a slot frees.
    pub max_connections: usize,
    /// Cap on live connections from any single peer IP (0 = unlimited).
    /// Unlike `max_connections` (which parks excess connects in the OS
    /// backlog), a per-IP violation REFUSES the connection outright —
    /// counted by the `server.conns_refused` metric — so one misbehaving
    /// volunteer cannot starve the rest of the fleet.
    pub max_conns_per_ip: usize,
    /// Reap server connections with no frame activity for this many
    /// seconds (0 = never). Parked consumers (blocked Consume /
    /// WaitVersion) are exempt.
    pub idle_timeout: u64,
    /// Event-loop shards: each is one loop thread owning its own
    /// connections and timers (on Linux with its own `SO_REUSEPORT`
    /// listener). 1 (default) = the classic single-loop server; capped
    /// at `obs::MAX_SHARDS`.
    pub loop_shards: usize,
    /// Readiness backend: "auto" (default — epoll on Linux, poll
    /// elsewhere), "poll", or "epoll" (Linux only).
    pub poller: String,
    // Observability (obs + `jsdoop metrics`).
    /// `serve` emits a JSON metrics line every N seconds (0 = off).
    pub metrics_every: u64,
    /// `jsdoop metrics --watch=N` re-renders every N seconds (0 = one
    /// shot).
    pub watch: u64,
    /// `jsdoop metrics --json` prints a JSON line instead of tables.
    pub json: bool,
    /// `jsdoop metrics --prom` prints Prometheus text exposition format
    /// (one scrape) instead of tables.
    pub prom: bool,
    // Multi-tenant fleets (queue/job).
    /// `jsdoop metrics --job=<id>` shows only that job's queue rows
    /// (`--job=` selects the default, unprefixed namespace). None = all.
    pub job: Option<String>,
    /// `serve --job_quotas=job=<max_msgs>:<max_bytes>,...` applies
    /// per-job admission caps at boot (0 = unlimited on that axis).
    /// Quotas are runtime policy, not journaled — re-apply here after
    /// every restart.
    pub job_quotas: String,
    /// Per-job aggregation-plan overrides on a multi-tenant fleet:
    /// `--job_agg=job=<plan>,...` where `<plan>` is any value `agg`
    /// accepts (`flat`, `tree:<fanin>`, `async:<tau>`). Jobs not listed
    /// fall back to the global `agg`.
    pub job_agg: String,
    // Corpus
    pub corpus_file: Option<PathBuf>,
    pub corpus_seed: u64,
    pub corpus_len: usize,
    // Reproducibility / simulation
    pub seed: u64,
    pub timeline_out: Option<PathBuf>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            batch_size: 128,
            examples_per_epoch: 2048,
            learning_rate: 0.1,
            epochs: 5,
            seq_len: 40,
            minibatch_size: 8,
            agg: "flat".to_string(),
            workers: 4,
            queue_addr: None,
            data_addr: None,
            artifact_dir: crate::runtime::default_artifact_dir(),
            visibility_timeout_secs: 120.0,
            task_poll_timeout_secs: 5.0,
            durability_dir: None,
            sync_policy: "every=64".to_string(),
            wal_compact_bytes: 64 << 20,
            wal_group_window_us: 0,
            replicate_from: None,
            promote: false,
            repl_poll_ms: 50,
            server_workers: 0,
            max_connections: 16_384,
            max_conns_per_ip: 0,
            idle_timeout: 0,
            loop_shards: 1,
            poller: "auto".to_string(),
            metrics_every: 0,
            watch: 0,
            json: false,
            prom: false,
            job: None,
            job_quotas: String::new(),
            job_agg: String::new(),
            corpus_file: None,
            corpus_seed: 1234,
            corpus_len: 200_000,
            seed: 42,
            timeline_out: None,
        }
    }
}

/// Keys whose bare `--flag` CLI form means `--flag=true`.
const BOOL_KEYS: &[&str] = &["promote", "json", "prom"];

impl Config {
    pub fn schedule(&self) -> Schedule {
        Schedule {
            seq_len: self.seq_len,
            batch_size: self.batch_size,
            minibatch_size: self.minibatch_size,
            examples_per_epoch: self.examples_per_epoch,
            epochs: self.epochs,
        }
    }

    /// The aggregation plan `agg` names (validated).
    pub fn agg_plan(&self) -> Result<crate::coordinator::agg::AggregationPlan> {
        self.agg.parse().context("bad agg")
    }

    pub fn validate(&self) -> Result<()> {
        self.schedule().validate()?;
        self.agg_plan()?;
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if !(self.learning_rate > 0.0) {
            bail!("learning_rate must be positive");
        }
        if self.visibility_timeout_secs <= 0.0 {
            bail!("visibility_timeout_secs must be positive");
        }
        self.sync_policy
            .parse::<crate::queue::durability::SyncPolicy>()
            .context("bad sync_policy")?;
        if self.wal_compact_bytes < 4096 {
            // A tiny threshold would snapshot-rewrite + fsync the whole
            // broker on every journaled op (0 would do it per record).
            bail!("wal_compact_bytes must be >= 4096");
        }
        if self.wal_group_window_us > 1_000_000 {
            // The window delays every waiting committer by up to its full
            // length; beyond a second it is certainly a typo'd unit.
            bail!("wal_group_window_us must be <= 1000000 (1s)");
        }
        if self.replicate_from.is_some() && self.durability_dir.is_none() {
            bail!("--replicate_from needs --durability_dir (the follower mirrors into it)");
        }
        if self.replicate_from.is_some() && self.promote {
            bail!(
                "--promote and --replicate_from are mutually exclusive: stop the \
                 follower, then restart with --promote only"
            );
        }
        if self.promote && self.durability_dir.is_none() {
            // Silently ignoring this would bring up an EMPTY in-memory
            // broker on the failover port — the worst possible surprise.
            bail!("--promote needs --durability_dir (the mirror to promote)");
        }
        if self.repl_poll_ms == 0 || self.repl_poll_ms > 60_000 {
            bail!("repl_poll_ms must be in 1..=60000");
        }
        if self.server_workers > 1024 {
            // The pool is meant to be small (ops are short and CPU-bound);
            // three extra digits is certainly a typo.
            bail!("server_workers must be <= 1024 (0 = auto)");
        }
        if self.max_connections == 0 {
            bail!("max_connections must be >= 1");
        }
        if self.idle_timeout > 86_400 {
            // A day-long "idle" cutoff is certainly a typo'd unit (ms?).
            bail!("idle_timeout must be <= 86400 seconds (0 = never reap)");
        }
        if self.loop_shards == 0 || self.loop_shards > crate::obs::MAX_SHARDS {
            bail!("loop_shards must be in 1..={}", crate::obs::MAX_SHARDS);
        }
        let poller = self
            .poller
            .parse::<crate::queue::server::PollerKind>()
            .context("bad poller")?;
        if poller == crate::queue::server::PollerKind::Epoll && !cfg!(target_os = "linux") {
            // Fail at validation, not at serve time on thread N.
            bail!("poller=epoll is linux-only on this build; use auto or poll");
        }
        if self.prom && self.json {
            bail!("--prom and --json are mutually exclusive output formats");
        }
        if self.metrics_every > 86_400 {
            bail!("metrics_every must be <= 86400 seconds (0 = off)");
        }
        if self.watch > 86_400 {
            bail!("watch must be <= 86400 seconds (0 = one shot)");
        }
        if let Some(job) = &self.job {
            // Empty selects the default namespace; anything else must be
            // a legal job id.
            if !job.is_empty() {
                crate::queue::job::validate_job_id(job).context("bad --job")?;
            }
        }
        self.job_quota_list()?;
        self.job_agg_list()?;
        if self.max_conns_per_ip > self.max_connections {
            bail!("max_conns_per_ip must be <= max_connections (0 = unlimited)");
        }
        Ok(())
    }

    /// The per-job admission caps `job_quotas` names (validated).
    pub fn job_quota_list(&self) -> Result<Vec<(String, crate::queue::job::JobQuota)>> {
        crate::queue::job::parse_quota_spec(&self.job_quotas).context("bad job_quotas")
    }

    /// The per-job aggregation plans `job_agg` names (validated): each
    /// entry is `job=<plan>` with `<plan>` in the `agg` grammar. Jobs
    /// not listed use the global `agg` plan.
    pub fn job_agg_list(
        &self,
    ) -> Result<Vec<(String, crate::coordinator::agg::AggregationPlan)>> {
        let mut out = Vec::new();
        for entry in self.job_agg.split(',').filter(|e| !e.trim().is_empty()) {
            let (job, plan) = entry
                .trim()
                .split_once('=')
                .with_context(|| format!("bad job_agg entry '{entry}': want job=<plan>"))?;
            crate::queue::job::validate_job_id(job.trim()).context("bad job_agg job id")?;
            let plan = plan
                .trim()
                .parse()
                .with_context(|| format!("bad job_agg plan for job '{}'", job.trim()))?;
            if out.iter().any(|(j, _)| j == job.trim()) {
                bail!("duplicate job_agg entry for job '{}'", job.trim());
            }
            out.push((job.trim().to_string(), plan));
        }
        Ok(out)
    }

    /// The plan a given job trains under: its `job_agg` override if one
    /// is listed, the global `agg` plan otherwise.
    pub fn agg_plan_for_job(
        &self,
        job: &str,
    ) -> Result<crate::coordinator::agg::AggregationPlan> {
        for (j, plan) in self.job_agg_list()? {
            if j == job {
                return Ok(plan);
            }
        }
        self.agg_plan()
    }

    /// Parse a `key = value` file ('#' comments, blank lines ok).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let mut cfg = Config::default();
        cfg.apply_pairs(parse_pairs(&text)?)?;
        Ok(cfg)
    }

    /// Apply `--key=value` CLI overrides (unknown keys are errors).
    pub fn apply_cli(&mut self, args: &[String]) -> Result<Vec<String>> {
        let mut rest = Vec::new();
        let mut pairs = BTreeMap::new();
        for a in args {
            if let Some(kv) = a.strip_prefix("--") {
                match kv.split_once('=') {
                    Some((k, v)) => {
                        pairs.insert(k.replace('-', "_"), v.to_string());
                    }
                    // Bare `--flag` means `--flag=true` — but ONLY for
                    // boolean keys. For string keys the bare form would
                    // silently store the literal "true" (`--replicate-from
                    // 127.0.0.1:7333` with a space would follow host
                    // "true" forever), so everything else stays the loud
                    // error it always was.
                    None => {
                        let key = kv.replace('-', "_");
                        if !BOOL_KEYS.contains(&key.as_str()) {
                            bail!("flag '{a}' needs =value");
                        }
                        pairs.insert(key, "true".to_string());
                    }
                }
            } else {
                rest.push(a.clone());
            }
        }
        self.apply_pairs(pairs)?;
        Ok(rest)
    }

    fn apply_pairs(&mut self, pairs: BTreeMap<String, String>) -> Result<()> {
        for (k, v) in pairs {
            self.set(&k, &v)?;
        }
        Ok(())
    }

    /// Set one field by name.
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        fn p<T: std::str::FromStr>(key: &str, v: &str) -> Result<T> {
            v.parse()
                .map_err(|_| anyhow::anyhow!("bad value '{v}' for {key}"))
        }
        match key {
            "batch_size" => self.batch_size = p(key, val)?,
            "examples_per_epoch" => self.examples_per_epoch = p(key, val)?,
            "learning_rate" => self.learning_rate = p(key, val)?,
            "epochs" => self.epochs = p(key, val)?,
            "seq_len" => self.seq_len = p(key, val)?,
            "minibatch_size" => self.minibatch_size = p(key, val)?,
            "agg" => self.agg = val.to_string(),
            "workers" => self.workers = p(key, val)?,
            "queue_addr" => self.queue_addr = Some(val.to_string()),
            "data_addr" => self.data_addr = Some(val.to_string()),
            "artifact_dir" => self.artifact_dir = PathBuf::from(val),
            "visibility_timeout_secs" => self.visibility_timeout_secs = p(key, val)?,
            "task_poll_timeout_secs" => self.task_poll_timeout_secs = p(key, val)?,
            "durability_dir" => self.durability_dir = Some(PathBuf::from(val)),
            "sync_policy" => self.sync_policy = val.to_string(),
            "wal_compact_bytes" => self.wal_compact_bytes = p(key, val)?,
            "wal_group_window_us" => self.wal_group_window_us = p(key, val)?,
            "replicate_from" => self.replicate_from = Some(val.to_string()),
            "promote" => self.promote = p(key, val)?,
            "repl_poll_ms" => self.repl_poll_ms = p(key, val)?,
            "server_workers" => self.server_workers = p(key, val)?,
            "max_connections" => self.max_connections = p(key, val)?,
            "max_conns_per_ip" => self.max_conns_per_ip = p(key, val)?,
            "idle_timeout" => self.idle_timeout = p(key, val)?,
            "loop_shards" => self.loop_shards = p(key, val)?,
            "poller" => self.poller = val.to_string(),
            "metrics_every" => self.metrics_every = p(key, val)?,
            "watch" => self.watch = p(key, val)?,
            "json" => self.json = p(key, val)?,
            "prom" => self.prom = p(key, val)?,
            "job" => self.job = Some(val.to_string()),
            "job_quotas" => self.job_quotas = val.to_string(),
            "job_agg" => self.job_agg = val.to_string(),
            "corpus_file" => self.corpus_file = Some(PathBuf::from(val)),
            "corpus_seed" => self.corpus_seed = p(key, val)?,
            "corpus_len" => self.corpus_len = p(key, val)?,
            "seed" => self.seed = p(key, val)?,
            "timeline_out" => self.timeline_out = Some(PathBuf::from(val)),
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }
}

fn parse_pairs(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("config line {} is not key = value: {raw:?}", lineno + 1);
        };
        out.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_config() {
        let c = Config::default();
        c.validate().unwrap();
        assert_eq!(c.batch_size, 128);
        assert_eq!(c.epochs, 5);
        assert_eq!(c.schedule().total_map_tasks(), 1280);
    }

    #[test]
    fn parse_pairs_and_comments() {
        let pairs = parse_pairs("a = 1\n# comment\n\nb= x  # trailing\n").unwrap();
        assert_eq!(pairs["a"], "1");
        assert_eq!(pairs["b"], "x");
        assert!(parse_pairs("no_equals_here\n").is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut c = Config::default();
        let rest = c
            .apply_cli(&[
                "--workers=32".into(),
                "--learning-rate=0.05".into(),
                "positional".into(),
            ])
            .unwrap();
        assert_eq!(c.workers, 32);
        assert_eq!(c.learning_rate, 0.05);
        assert_eq!(rest, vec!["positional"]);
        assert!(c.apply_cli(&["--nope=1".into()]).is_err());
        assert!(c.apply_cli(&["--workers".into()]).is_err());
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = Config::default();
        c.workers = 0;
        assert!(c.validate().is_err());
        let mut c2 = Config::default();
        c2.learning_rate = -1.0;
        assert!(c2.validate().is_err());
    }

    #[test]
    fn agg_key_parses_and_validates() {
        use crate::coordinator::agg::AggregationPlan;
        let mut c = Config::default();
        assert_eq!(c.agg_plan().unwrap(), AggregationPlan::Flat);
        c.apply_cli(&["--agg=tree:4".into()]).unwrap();
        assert_eq!(c.agg_plan().unwrap(), AggregationPlan::Tree { fanin: 4 });
        c.validate().unwrap();
        c.agg = "tree:1".into();
        assert!(c.validate().is_err());
        c.agg = "ring".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn replication_keys_parse_and_validate() {
        let mut c = Config::default();
        c.apply_cli(&[
            "--durability_dir=/tmp/mirror".into(),
            "--replicate-from=127.0.0.1:7333".into(),
            "--repl_poll_ms=20".into(),
        ])
        .unwrap();
        assert_eq!(c.replicate_from.as_deref(), Some("127.0.0.1:7333"));
        assert_eq!(c.repl_poll_ms, 20);
        c.validate().unwrap();
        // A follower needs somewhere to mirror into.
        c.durability_dir = None;
        assert!(c.validate().is_err());
        c.durability_dir = Some(PathBuf::from("/tmp/mirror"));
        // Promote-while-following is contradictory.
        c.apply_cli(&["--promote".into()]).unwrap(); // bare flag = true
        assert!(c.promote);
        assert!(c.validate().is_err());
        c.replicate_from = None;
        c.validate().unwrap();
        // Promoting nothing must be an error, not an empty broker.
        c.durability_dir = None;
        assert!(c.validate().is_err());
        c.durability_dir = Some(PathBuf::from("/tmp/mirror"));
        c.repl_poll_ms = 0;
        assert!(c.validate().is_err());
        // Bare non-boolean flags still fail loudly — a space instead of
        // `=` must never silently store the literal "true".
        let mut c2 = Config::default();
        assert!(c2.apply_cli(&["--workers".into()]).is_err());
        assert!(c2.apply_cli(&["--replicate-from".into()]).is_err());
        assert!(c2.apply_cli(&["--durability_dir".into()]).is_err());
    }

    #[test]
    fn server_keys_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.server_workers, 0); // auto
        assert_eq!(c.max_connections, 16_384);
        c.apply_cli(&["--server-workers=2".into(), "--max-connections=512".into()]).unwrap();
        assert_eq!(c.server_workers, 2);
        assert_eq!(c.max_connections, 512);
        c.validate().unwrap();
        c.max_connections = 0;
        assert!(c.validate().is_err());
        c.max_connections = 512;
        c.server_workers = 4096; // typo'd pool size
        assert!(c.validate().is_err());
    }

    #[test]
    fn event_loop_keys_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.loop_shards, 1); // classic single loop
        assert_eq!(c.poller, "auto");
        c.apply_cli(&["--loop-shards=4".into(), "--poller=poll".into()]).unwrap();
        assert_eq!(c.loop_shards, 4);
        assert_eq!(c.poller, "poll");
        c.validate().unwrap();
        c.loop_shards = 0;
        assert!(c.validate().is_err());
        c.loop_shards = crate::obs::MAX_SHARDS + 1;
        assert!(c.validate().is_err());
        c.loop_shards = crate::obs::MAX_SHARDS;
        c.validate().unwrap();
        // Unknown backends fail loudly at validation.
        c.poller = "kqueue".into();
        assert!(c.validate().is_err());
        // An explicit epoll request is validated against the build target
        // (it must not fail later on a shard thread).
        c.poller = "epoll".into();
        assert_eq!(c.validate().is_ok(), cfg!(target_os = "linux"));
    }

    #[test]
    fn prom_key_parses_and_conflicts_with_json() {
        let mut c = Config::default();
        assert!(!c.prom);
        c.apply_cli(&["--prom".into()]).unwrap(); // bare boolean flag
        assert!(c.prom);
        c.validate().unwrap();
        c.json = true; // two output formats, one stream
        assert!(c.validate().is_err());
    }

    #[test]
    fn observability_keys_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.idle_timeout, 0); // never reap
        assert_eq!(c.metrics_every, 0); // off
        c.apply_cli(&[
            "--idle-timeout=30".into(),
            "--metrics-every=5".into(),
            "--watch=2".into(),
            "--json".into(), // bare boolean flag
        ])
        .unwrap();
        assert_eq!(c.idle_timeout, 30);
        assert_eq!(c.metrics_every, 5);
        assert_eq!(c.watch, 2);
        assert!(c.json);
        c.validate().unwrap();
        // A day-plus cutoff is a typo'd unit, not a policy.
        c.idle_timeout = 100_000;
        assert!(c.validate().is_err());
        c.idle_timeout = 0;
        c.metrics_every = 100_000;
        assert!(c.validate().is_err());
        c.metrics_every = 0;
        c.watch = 100_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn multi_tenant_keys_parse_and_validate() {
        let mut c = Config::default();
        c.apply_cli(&[
            "--job=alpha".into(),
            "--job-quotas=heavy=1000:1048576,light=0:0".into(),
        ])
        .unwrap();
        assert_eq!(c.job.as_deref(), Some("alpha"));
        let quotas = c.job_quota_list().unwrap();
        assert_eq!(quotas.len(), 2);
        assert_eq!(quotas[0].0, "heavy");
        assert_eq!(quotas[0].1.max_ready_msgs, 1000);
        assert_eq!(quotas[0].1.max_ready_bytes, 1 << 20);
        assert!(quotas[1].1.is_unlimited());
        c.validate().unwrap();
        // Job ids obey the namespace grammar ('/' is the separator).
        c.job = Some("a/b".into());
        assert!(c.validate().is_err());
        // `--job=` (empty) legally selects the default namespace.
        c.job = Some(String::new());
        c.validate().unwrap();
        c.job_quotas = "heavy=nope".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn job_agg_key_parses_and_validates() {
        use crate::coordinator::agg::AggregationPlan;
        let mut c = Config::default();
        c.apply_cli(&["--job-agg=lstm=flat,mlp=tree:2,big=async:4".into()]).unwrap();
        c.validate().unwrap();
        let plans = c.job_agg_list().unwrap();
        assert_eq!(
            plans,
            vec![
                ("lstm".to_string(), AggregationPlan::Flat),
                ("mlp".to_string(), AggregationPlan::Tree { fanin: 2 }),
                ("big".to_string(), AggregationPlan::Async { tau: 4 }),
            ]
        );
        // Listed jobs get their override; everyone else the global plan.
        assert_eq!(c.agg_plan_for_job("mlp").unwrap(), AggregationPlan::Tree { fanin: 2 });
        assert_eq!(c.agg_plan_for_job("other").unwrap(), AggregationPlan::Flat);
        // Empty = no overrides (the default).
        c.job_agg = String::new();
        assert!(c.job_agg_list().unwrap().is_empty());
        // Bad plan grammar, bad job id, missing '=', duplicates: loud.
        c.job_agg = "lstm=ring".into();
        assert!(c.validate().is_err());
        c.job_agg = "a/b=flat".into();
        assert!(c.validate().is_err());
        c.job_agg = "flat".into();
        assert!(c.validate().is_err());
        c.job_agg = "lstm=flat,lstm=tree:2".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn max_conns_per_ip_parses_and_validates() {
        let mut c = Config::default();
        assert_eq!(c.max_conns_per_ip, 0, "default: unlimited");
        c.apply_cli(&["--max-conns-per-ip=4".into()]).unwrap();
        assert_eq!(c.max_conns_per_ip, 4);
        c.validate().unwrap();
        // A per-IP cap above the global cap could never bind.
        c.max_conns_per_ip = c.max_connections + 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn durability_keys_parse_and_validate() {
        let mut c = Config::default();
        c.apply_cli(&[
            "--durability_dir=/tmp/wal".into(),
            "--sync-policy=always".into(),
            "--wal_compact_bytes=1048576".into(),
            "--wal_group_window_us=250".into(),
        ])
        .unwrap();
        assert_eq!(c.durability_dir, Some(PathBuf::from("/tmp/wal")));
        assert_eq!(c.sync_policy, "always");
        assert_eq!(c.wal_compact_bytes, 1 << 20);
        assert_eq!(c.wal_group_window_us, 250);
        c.validate().unwrap();
        c.wal_group_window_us = 2_000_000; // 2s: typo'd unit
        assert!(c.validate().is_err());
        c.wal_group_window_us = 0;
        c.sync_policy = "whenever".into();
        assert!(c.validate().is_err());
        c.sync_policy = "never".into();
        c.wal_compact_bytes = 0; // would compact per record
        assert!(c.validate().is_err());
    }
}
