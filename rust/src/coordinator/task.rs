//! Task model (paper §IV.F): the Initiator divides training into *map*
//! tasks (compute one minibatch gradient against model version v) and
//! *reduce* tasks (accumulate the batch's minibatch gradients, update the
//! model v -> v+1). Tasks and results are plain byte payloads on the queue
//! — volunteers need no a-priori knowledge beyond the task codec, exactly
//! like the paper's browser workers downloading task code + params.

use anyhow::{bail, Result};

use crate::util::{f32_from_le_bytes, f32_to_le_bytes};

/// Position of a batch in the training run. `global_index = epoch * batches_per_epoch + batch`
/// doubles as the model version the batch's map tasks require.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchRef {
    pub epoch: u32,
    pub batch: u32,
}

impl BatchRef {
    pub fn global_index(&self, batches_per_epoch: u32) -> u64 {
        self.epoch as u64 * batches_per_epoch as u64 + self.batch as u64
    }
}

/// A unit of volunteer work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Task {
    /// Compute the gradient of minibatch `minibatch` of `batch_ref` against
    /// model version `model_version`; publish a `GradResult`.
    Map {
        batch_ref: BatchRef,
        minibatch: u32,
        model_version: u64,
    },
    /// Collect `num_minibatches` gradients for `batch_ref`, fold them in
    /// index order, RMSprop-update model `model_version` -> `+1`.
    Reduce {
        batch_ref: BatchRef,
        num_minibatches: u32,
        model_version: u64,
    },
}

const TAG_MAP: u8 = 1;
const TAG_REDUCE: u8 = 2;

impl Task {
    pub fn model_version(&self) -> u64 {
        match self {
            Task::Map { model_version, .. } | Task::Reduce { model_version, .. } => *model_version,
        }
    }

    pub fn batch_ref(&self) -> BatchRef {
        match self {
            Task::Map { batch_ref, .. } | Task::Reduce { batch_ref, .. } => *batch_ref,
        }
    }

    pub fn kind_str(&self) -> &'static str {
        match self {
            Task::Map { .. } => "map",
            Task::Reduce { .. } => "reduce",
        }
    }

    /// Compact fixed-layout binary codec (wire + queue payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(25);
        match self {
            Task::Map { batch_ref, minibatch, model_version } => {
                b.push(TAG_MAP);
                b.extend_from_slice(&batch_ref.epoch.to_le_bytes());
                b.extend_from_slice(&batch_ref.batch.to_le_bytes());
                b.extend_from_slice(&minibatch.to_le_bytes());
                b.extend_from_slice(&model_version.to_le_bytes());
            }
            Task::Reduce { batch_ref, num_minibatches, model_version } => {
                b.push(TAG_REDUCE);
                b.extend_from_slice(&batch_ref.epoch.to_le_bytes());
                b.extend_from_slice(&batch_ref.batch.to_le_bytes());
                b.extend_from_slice(&num_minibatches.to_le_bytes());
                b.extend_from_slice(&model_version.to_le_bytes());
            }
        }
        b
    }

    pub fn decode(b: &[u8]) -> Result<Task> {
        if b.len() != 21 {
            bail!("task payload must be 21 bytes, got {}", b.len());
        }
        let u32at = |i: usize| u32::from_le_bytes(b[i..i + 4].try_into().unwrap());
        let u64at = |i: usize| u64::from_le_bytes(b[i..i + 8].try_into().unwrap());
        let batch_ref = BatchRef { epoch: u32at(1), batch: u32at(5) };
        match b[0] {
            TAG_MAP => Ok(Task::Map {
                batch_ref,
                minibatch: u32at(9),
                model_version: u64at(13),
            }),
            TAG_REDUCE => Ok(Task::Reduce {
                batch_ref,
                num_minibatches: u32at(9),
                model_version: u64at(13),
            }),
            t => bail!("unknown task tag {t}"),
        }
    }
}

/// Result of a map task, published to the batch's results queue.
#[derive(Debug, Clone, PartialEq)]
pub struct GradResult {
    pub batch_ref: BatchRef,
    pub minibatch: u32,
    pub loss: f32,
    pub grads: Vec<f32>,
}

impl GradResult {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(20 + self.grads.len() * 4);
        b.extend_from_slice(&self.batch_ref.epoch.to_le_bytes());
        b.extend_from_slice(&self.batch_ref.batch.to_le_bytes());
        b.extend_from_slice(&self.minibatch.to_le_bytes());
        b.extend_from_slice(&self.loss.to_le_bytes());
        b.extend_from_slice(&(self.grads.len() as u32).to_le_bytes());
        b.extend_from_slice(&f32_to_le_bytes(&self.grads));
        b
    }

    pub fn decode(b: &[u8]) -> Result<GradResult> {
        if b.len() < 20 {
            bail!("grad result too short");
        }
        let u32at = |i: usize| u32::from_le_bytes(b[i..i + 4].try_into().unwrap());
        let n = u32at(16) as usize;
        if b.len() != 20 + n * 4 {
            bail!("grad result length mismatch");
        }
        Ok(GradResult {
            batch_ref: BatchRef { epoch: u32at(0), batch: u32at(4) },
            minibatch: u32at(8),
            loss: f32::from_le_bytes(b[12..16].try_into().unwrap()),
            grads: f32_from_le_bytes(&b[20..]),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_codec_roundtrip() {
        let tasks = [
            Task::Map {
                batch_ref: BatchRef { epoch: 3, batch: 11 },
                minibatch: 7,
                model_version: 59,
            },
            Task::Reduce {
                batch_ref: BatchRef { epoch: 0, batch: 0 },
                num_minibatches: 16,
                model_version: 0,
            },
        ];
        for t in tasks {
            assert_eq!(Task::decode(&t.encode()).unwrap(), t);
        }
    }

    #[test]
    fn task_decode_rejects_garbage() {
        assert!(Task::decode(&[]).is_err());
        assert!(Task::decode(&[9; 21]).is_err());
        assert!(Task::decode(&[1; 20]).is_err());
    }

    #[test]
    fn grad_result_roundtrip() {
        let g = GradResult {
            batch_ref: BatchRef { epoch: 1, batch: 2 },
            minibatch: 5,
            loss: 4.58,
            grads: vec![0.25, -1.5, 3.0],
        };
        assert_eq!(GradResult::decode(&g.encode()).unwrap(), g);
    }

    #[test]
    fn grad_result_rejects_truncation() {
        let g = GradResult {
            batch_ref: BatchRef { epoch: 0, batch: 0 },
            minibatch: 0,
            loss: 0.0,
            grads: vec![1.0],
        };
        let mut b = g.encode();
        b.pop();
        assert!(GradResult::decode(&b).is_err());
    }

    #[test]
    fn global_index() {
        let b = BatchRef { epoch: 2, batch: 3 };
        assert_eq!(b.global_index(16), 35);
    }
}
