//! Task model (paper §IV.F): the Initiator divides training into *map*
//! tasks (compute one minibatch gradient against model version v) and
//! *reduce* tasks (accumulate the batch's minibatch gradients, update the
//! model v -> v+1). Tasks and results are plain byte payloads on the queue
//! — volunteers need no a-priori knowledge beyond the task codec, exactly
//! like the paper's browser workers downloading task code + params.
//!
//! Under a [`AggregationPlan::Tree`] plan the Initiator additionally
//! emits *combine* tasks: fold a disjoint slot-range of the batch's
//! gradients into one partial-sum [`GradResult`] on the next level's
//! queue (see coordinator/agg.rs). Under an [`AggregationPlan::Async`]
//! plan the staleness bound τ rides dedicated task tags (the flat layouts
//! plus a trailing `tau u64`), and map results carry their true base
//! version in a [`ModelUpdate`](crate::model::ModelUpdate) header. The
//! flat encodings are frozen — a tag-2 Reduce payload is byte-for-byte
//! what it always was, and legacy single-minibatch gradient payloads
//! still decode — so mixed-version fleets and the golden flat task
//! stream both keep working.

use anyhow::{bail, Result};

use crate::coordinator::agg::AggregationPlan;
use crate::util::{f32_from_le_bytes, f32_to_le_bytes};

/// Position of a batch in the training run. `global_index = epoch * batches_per_epoch + batch`
/// doubles as the model version the batch's map tasks require.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchRef {
    pub epoch: u32,
    pub batch: u32,
}

impl BatchRef {
    pub fn global_index(&self, batches_per_epoch: u32) -> u64 {
        self.epoch as u64 * batches_per_epoch as u64 + self.batch as u64
    }
}

/// A unit of volunteer work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Task {
    /// Compute the gradient of minibatch `minibatch` of `batch_ref` against
    /// model version `model_version`; publish a `GradResult`.
    ///
    /// `staleness`: `None` is the paper's barrier (pin exactly
    /// `model_version`, wait until it exists). `Some(tau)` is the
    /// bounded-staleness plan: compute against whatever model is current
    /// once it has reached `model_version - tau`, and publish a
    /// [`ModelUpdate`](crate::model::ModelUpdate) carrying the version
    /// actually used.
    Map {
        batch_ref: BatchRef,
        minibatch: u32,
        model_version: u64,
        staleness: Option<u64>,
    },
    /// Collect the batch's top-level partials (under `plan`; for
    /// [`AggregationPlan::Flat`] that is all `num_minibatches` leaf
    /// gradients), fold them in slot-index order, RMSprop-update model
    /// `model_version` -> `+1`.
    Reduce {
        batch_ref: BatchRef,
        num_minibatches: u32,
        model_version: u64,
        plan: AggregationPlan,
    },
    /// Tree plans only: fold the level-(`level`-1) results covering leaf
    /// slots `[slot_lo, slot_hi)` into one partial sum on the `level`
    /// queue. `fanin` pins the plan so the combiner can derive its child
    /// ranges (and the producer tasks to republish if a payload poisons).
    Combine {
        batch_ref: BatchRef,
        level: u32,
        slot_lo: u32,
        slot_hi: u32,
        fanin: u32,
        model_version: u64,
    },
}

const TAG_MAP: u8 = 1;
const TAG_REDUCE: u8 = 2; // frozen flat layout (legacy wire format)
const TAG_COMBINE: u8 = 3;
const TAG_REDUCE_TREE: u8 = 4;
const TAG_REDUCE_ASYNC: u8 = 5; // flat reduce layout + tau u64
const TAG_MAP_ASYNC: u8 = 6; // flat map layout + tau u64

impl Task {
    pub fn model_version(&self) -> u64 {
        match self {
            Task::Map { model_version, .. }
            | Task::Reduce { model_version, .. }
            | Task::Combine { model_version, .. } => *model_version,
        }
    }

    pub fn batch_ref(&self) -> BatchRef {
        match self {
            Task::Map { batch_ref, .. }
            | Task::Reduce { batch_ref, .. }
            | Task::Combine { batch_ref, .. } => *batch_ref,
        }
    }

    pub fn kind_str(&self) -> &'static str {
        match self {
            Task::Map { .. } => "map",
            Task::Reduce { .. } => "reduce",
            Task::Combine { .. } => "combine",
        }
    }

    /// Within-batch stage for the priority order (and the priority-swap
    /// `precedes` rule): maps at 0, a combine at its output level, the
    /// reduce last. See [`AggregationPlan::task_priority`].
    pub fn stage(&self) -> u32 {
        match self {
            Task::Map { .. } => 0,
            Task::Combine { level, .. } => *level,
            Task::Reduce { .. } => u32::MAX,
        }
    }

    /// Compact fixed-layout binary codec (wire + queue payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(33);
        match self {
            Task::Map { batch_ref, minibatch, model_version, staleness } => {
                // Barrier maps keep the frozen 21-byte tag-1 layout; the
                // async variant appends its staleness bound.
                b.push(if staleness.is_some() { TAG_MAP_ASYNC } else { TAG_MAP });
                b.extend_from_slice(&batch_ref.epoch.to_le_bytes());
                b.extend_from_slice(&batch_ref.batch.to_le_bytes());
                b.extend_from_slice(&minibatch.to_le_bytes());
                b.extend_from_slice(&model_version.to_le_bytes());
                if let Some(tau) = staleness {
                    b.extend_from_slice(&tau.to_le_bytes());
                }
            }
            Task::Reduce { batch_ref, num_minibatches, model_version, plan } => match plan {
                AggregationPlan::Flat => {
                    b.push(TAG_REDUCE);
                    b.extend_from_slice(&batch_ref.epoch.to_le_bytes());
                    b.extend_from_slice(&batch_ref.batch.to_le_bytes());
                    b.extend_from_slice(&num_minibatches.to_le_bytes());
                    b.extend_from_slice(&model_version.to_le_bytes());
                }
                AggregationPlan::Tree { fanin } => {
                    b.push(TAG_REDUCE_TREE);
                    b.extend_from_slice(&batch_ref.epoch.to_le_bytes());
                    b.extend_from_slice(&batch_ref.batch.to_le_bytes());
                    b.extend_from_slice(&num_minibatches.to_le_bytes());
                    b.extend_from_slice(&model_version.to_le_bytes());
                    b.extend_from_slice(&fanin.to_le_bytes());
                }
                AggregationPlan::Async { tau } => {
                    b.push(TAG_REDUCE_ASYNC);
                    b.extend_from_slice(&batch_ref.epoch.to_le_bytes());
                    b.extend_from_slice(&batch_ref.batch.to_le_bytes());
                    b.extend_from_slice(&num_minibatches.to_le_bytes());
                    b.extend_from_slice(&model_version.to_le_bytes());
                    b.extend_from_slice(&tau.to_le_bytes());
                }
            },
            Task::Combine { batch_ref, level, slot_lo, slot_hi, fanin, model_version } => {
                b.push(TAG_COMBINE);
                b.extend_from_slice(&batch_ref.epoch.to_le_bytes());
                b.extend_from_slice(&batch_ref.batch.to_le_bytes());
                b.extend_from_slice(&level.to_le_bytes());
                b.extend_from_slice(&model_version.to_le_bytes());
                b.extend_from_slice(&slot_lo.to_le_bytes());
                b.extend_from_slice(&slot_hi.to_le_bytes());
                b.extend_from_slice(&fanin.to_le_bytes());
            }
        }
        b
    }

    pub fn decode(b: &[u8]) -> Result<Task> {
        // Every variant is a fixed layout; lengths are compared exactly
        // (never computed by multiplying an attacker-controlled count —
        // the overflow audit of decode_record/wire.rs applies here too).
        if b.is_empty() {
            bail!("empty task payload");
        }
        let u32at = |i: usize| u32::from_le_bytes(b[i..i + 4].try_into().unwrap());
        let u64at = |i: usize| u64::from_le_bytes(b[i..i + 8].try_into().unwrap());
        match b[0] {
            TAG_MAP => {
                if b.len() != 21 {
                    bail!("map task payload must be 21 bytes, got {}", b.len());
                }
                let minibatch = u32at(9);
                if minibatch == u32::MAX {
                    // Its leaf GradResult covers [m, m+1): the slot bound
                    // must not wrap (same guard as the gradient decoder).
                    bail!("map task minibatch index out of range");
                }
                Ok(Task::Map {
                    batch_ref: BatchRef { epoch: u32at(1), batch: u32at(5) },
                    minibatch,
                    model_version: u64at(13),
                    staleness: None,
                })
            }
            TAG_MAP_ASYNC => {
                if b.len() != 29 {
                    bail!("async map task payload must be 29 bytes, got {}", b.len());
                }
                let minibatch = u32at(9);
                if minibatch == u32::MAX {
                    bail!("map task minibatch index out of range");
                }
                Ok(Task::Map {
                    batch_ref: BatchRef { epoch: u32at(1), batch: u32at(5) },
                    minibatch,
                    model_version: u64at(13),
                    staleness: Some(u64at(21)),
                })
            }
            TAG_REDUCE => {
                if b.len() != 21 {
                    bail!("reduce task payload must be 21 bytes, got {}", b.len());
                }
                if u32at(9) == 0 {
                    // A 0-minibatch reduce would panic the accumulator.
                    bail!("reduce task with zero minibatches");
                }
                Ok(Task::Reduce {
                    batch_ref: BatchRef { epoch: u32at(1), batch: u32at(5) },
                    num_minibatches: u32at(9),
                    model_version: u64at(13),
                    plan: AggregationPlan::Flat,
                })
            }
            TAG_REDUCE_TREE => {
                if b.len() != 25 {
                    bail!("tree reduce payload must be 25 bytes, got {}", b.len());
                }
                let fanin = u32at(21);
                if fanin < 2 {
                    bail!("tree reduce fanin must be >= 2, got {fanin}");
                }
                if u32at(9) == 0 {
                    bail!("reduce task with zero minibatches");
                }
                Ok(Task::Reduce {
                    batch_ref: BatchRef { epoch: u32at(1), batch: u32at(5) },
                    num_minibatches: u32at(9),
                    model_version: u64at(13),
                    plan: AggregationPlan::Tree { fanin },
                })
            }
            TAG_REDUCE_ASYNC => {
                if b.len() != 29 {
                    bail!("async reduce payload must be 29 bytes, got {}", b.len());
                }
                if u32at(9) == 0 {
                    bail!("reduce task with zero minibatches");
                }
                Ok(Task::Reduce {
                    batch_ref: BatchRef { epoch: u32at(1), batch: u32at(5) },
                    num_minibatches: u32at(9),
                    model_version: u64at(13),
                    plan: AggregationPlan::Async { tau: u64at(21) },
                })
            }
            TAG_COMBINE => {
                if b.len() != 33 {
                    bail!("combine task payload must be 33 bytes, got {}", b.len());
                }
                let (level, slot_lo, slot_hi, fanin) = (u32at(9), u32at(21), u32at(25), u32at(29));
                if level == 0 {
                    bail!("combine level must be >= 1");
                }
                if slot_lo >= slot_hi {
                    bail!("combine slot range [{slot_lo}, {slot_hi}) is empty");
                }
                if fanin < 2 {
                    bail!("combine fanin must be >= 2, got {fanin}");
                }
                Ok(Task::Combine {
                    batch_ref: BatchRef { epoch: u32at(1), batch: u32at(5) },
                    level,
                    slot_lo,
                    slot_hi,
                    fanin,
                    model_version: u64at(13),
                })
            }
            t => bail!("unknown task tag {t}"),
        }
    }
}

/// Magic first-u32 of the versioned [`GradResult`] layout. Legacy
/// payloads start with the epoch, which never plausibly reaches
/// `u32::MAX` (the same discriminator trick as the broker's snapshot
/// header).
const GRAD_MAGIC: u32 = u32::MAX;
const GRAD_VERSION: u32 = 1;

/// A gradient message on a batch's results queues: either a leaf (one
/// minibatch gradient from a map task — the paper's wire format) or a
/// partial SUM over the leaf slot-range `[slot_lo, slot_hi)` produced by
/// a combine task.
///
/// `weight` is the number of leaf gradients folded into `grads` (always
/// `slot_hi - slot_lo`; carried explicitly on the wire so a decoder never
/// has to trust arithmetic on the range). `loss` is the weight-weighted
/// mean of the covered leaves' losses (informational).
#[derive(Debug, Clone, PartialEq)]
pub struct GradResult {
    pub batch_ref: BatchRef,
    pub slot_lo: u32,
    pub slot_hi: u32,
    pub weight: u32,
    pub loss: f32,
    pub grads: Vec<f32>,
}

impl GradResult {
    /// A map task's result: the raw gradient of one minibatch slot.
    pub fn leaf(batch_ref: BatchRef, minibatch: u32, loss: f32, grads: Vec<f32>) -> Self {
        GradResult { batch_ref, slot_lo: minibatch, slot_hi: minibatch + 1, weight: 1, loss, grads }
    }

    pub fn is_leaf(&self) -> bool {
        self.weight == 1 && self.slot_hi == self.slot_lo + 1
    }

    /// Leaves encode in the legacy layout (epoch, batch, minibatch, loss,
    /// n, grads — byte-identical to the original protocol); partials use
    /// the versioned layout behind [`GRAD_MAGIC`].
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(36 + self.grads.len() * 4);
        if self.is_leaf() {
            b.extend_from_slice(&self.batch_ref.epoch.to_le_bytes());
            b.extend_from_slice(&self.batch_ref.batch.to_le_bytes());
            b.extend_from_slice(&self.slot_lo.to_le_bytes());
            b.extend_from_slice(&self.loss.to_le_bytes());
            b.extend_from_slice(&(self.grads.len() as u32).to_le_bytes());
        } else {
            b.extend_from_slice(&GRAD_MAGIC.to_le_bytes());
            b.extend_from_slice(&GRAD_VERSION.to_le_bytes());
            b.extend_from_slice(&self.batch_ref.epoch.to_le_bytes());
            b.extend_from_slice(&self.batch_ref.batch.to_le_bytes());
            b.extend_from_slice(&self.slot_lo.to_le_bytes());
            b.extend_from_slice(&self.slot_hi.to_le_bytes());
            b.extend_from_slice(&self.weight.to_le_bytes());
            b.extend_from_slice(&self.loss.to_le_bytes());
            b.extend_from_slice(&(self.grads.len() as u32).to_le_bytes());
        }
        b.extend_from_slice(&f32_to_le_bytes(&self.grads));
        b
    }

    pub fn decode(b: &[u8]) -> Result<GradResult> {
        if b.len() < 20 {
            bail!("grad result too short");
        }
        let u32at = |i: usize| u32::from_le_bytes(b[i..i + 4].try_into().unwrap());
        if u32at(0) == GRAD_MAGIC {
            let version = u32at(4);
            if version != GRAD_VERSION {
                bail!("grad result version {version} is newer than this binary");
            }
            if b.len() < 36 {
                bail!("versioned grad result too short");
            }
            let n = u32at(32) as usize;
            // Division form: `n * 4` wraps a 32-bit usize for a corrupt
            // count (same audit as decode_record / wire.rs).
            if (b.len() - 36) / 4 != n || (b.len() - 36) % 4 != 0 {
                bail!("grad result length mismatch");
            }
            let (slot_lo, slot_hi, weight) = (u32at(16), u32at(20), u32at(24));
            if slot_lo >= slot_hi {
                bail!("grad result slot range [{slot_lo}, {slot_hi}) is empty");
            }
            if weight != slot_hi - slot_lo {
                bail!("grad result weight {weight} != covered slots {}", slot_hi - slot_lo);
            }
            Ok(GradResult {
                batch_ref: BatchRef { epoch: u32at(8), batch: u32at(12) },
                slot_lo,
                slot_hi,
                weight,
                loss: f32::from_le_bytes(b[28..32].try_into().unwrap()),
                grads: f32_from_le_bytes(&b[36..]),
            })
        } else {
            // Legacy single-minibatch layout.
            let n = u32at(16) as usize;
            if (b.len() - 20) / 4 != n || (b.len() - 20) % 4 != 0 {
                bail!("grad result length mismatch");
            }
            let minibatch = u32at(8);
            if minibatch == u32::MAX {
                bail!("grad result minibatch index out of range");
            }
            Ok(GradResult::leaf(
                BatchRef { epoch: u32at(0), batch: u32at(4) },
                minibatch,
                f32::from_le_bytes(b[12..16].try_into().unwrap()),
                f32_from_le_bytes(&b[20..]),
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_codec_roundtrip() {
        let tasks = [
            Task::Map {
                batch_ref: BatchRef { epoch: 3, batch: 11 },
                minibatch: 7,
                model_version: 59,
                staleness: None,
            },
            Task::Map {
                batch_ref: BatchRef { epoch: 3, batch: 11 },
                minibatch: 7,
                model_version: 59,
                staleness: Some(4),
            },
            Task::Reduce {
                batch_ref: BatchRef { epoch: 0, batch: 0 },
                num_minibatches: 16,
                model_version: 0,
                plan: AggregationPlan::Flat,
            },
            Task::Reduce {
                batch_ref: BatchRef { epoch: 2, batch: 9 },
                num_minibatches: 16,
                model_version: 41,
                plan: AggregationPlan::Tree { fanin: 4 },
            },
            Task::Combine {
                batch_ref: BatchRef { epoch: 1, batch: 5 },
                level: 2,
                slot_lo: 8,
                slot_hi: 16,
                fanin: 2,
                model_version: 21,
            },
            Task::Reduce {
                batch_ref: BatchRef { epoch: 2, batch: 9 },
                num_minibatches: 16,
                model_version: 41,
                plan: AggregationPlan::Async { tau: 3 },
            },
            Task::Reduce {
                batch_ref: BatchRef { epoch: 0, batch: 1 },
                num_minibatches: 8,
                model_version: 1,
                plan: AggregationPlan::Async { tau: 0 },
            },
        ];
        for t in tasks {
            assert_eq!(Task::decode(&t.encode()).unwrap(), t);
        }
    }

    #[test]
    fn flat_reduce_encoding_is_frozen() {
        // The golden flat task stream depends on this exact layout.
        let t = Task::Reduce {
            batch_ref: BatchRef { epoch: 1, batch: 2 },
            num_minibatches: 16,
            model_version: 18,
            plan: AggregationPlan::Flat,
        };
        let mut expect = vec![2u8]; // TAG_REDUCE
        expect.extend_from_slice(&1u32.to_le_bytes());
        expect.extend_from_slice(&2u32.to_le_bytes());
        expect.extend_from_slice(&16u32.to_le_bytes());
        expect.extend_from_slice(&18u64.to_le_bytes());
        assert_eq!(t.encode(), expect);
        assert_eq!(expect.len(), 21);
    }

    #[test]
    fn task_decode_rejects_garbage() {
        assert!(Task::decode(&[]).is_err());
        assert!(Task::decode(&[9; 21]).is_err());
        assert!(Task::decode(&[1; 20]).is_err());
        // A map with minibatch u32::MAX would overflow its leaf's
        // [m, m+1) slot bound — reject at decode, not panic later.
        let mut m = Task::Map {
            batch_ref: BatchRef { epoch: 0, batch: 0 },
            minibatch: 0,
            model_version: 0,
            staleness: None,
        }
        .encode();
        m[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Task::decode(&m).is_err());
        // A reduce claiming zero minibatches would panic the accumulator.
        let mut r = Task::Reduce {
            batch_ref: BatchRef { epoch: 0, batch: 0 },
            num_minibatches: 1,
            model_version: 0,
            plan: AggregationPlan::Flat,
        }
        .encode();
        r[9..13].copy_from_slice(&0u32.to_le_bytes());
        assert!(Task::decode(&r).is_err());
        // Per-tag length mismatches on the new variants.
        assert!(Task::decode(&[3; 21]).is_err()); // combine needs 33
        assert!(Task::decode(&[4; 21]).is_err()); // tree reduce needs 25
        assert!(Task::decode(&[4; 26]).is_err());
        // Structurally invalid combines/reduces.
        let good = Task::Combine {
            batch_ref: BatchRef { epoch: 0, batch: 0 },
            level: 1,
            slot_lo: 0,
            slot_hi: 4,
            fanin: 4,
            model_version: 0,
        };
        let mut b = good.encode();
        b[9..13].copy_from_slice(&0u32.to_le_bytes()); // level 0
        assert!(Task::decode(&b).is_err());
        let mut b = good.encode();
        b[25..29].copy_from_slice(&0u32.to_le_bytes()); // slot_hi == 0 <= slot_lo
        assert!(Task::decode(&b).is_err());
        let mut b = good.encode();
        b[29..33].copy_from_slice(&1u32.to_le_bytes()); // fanin 1
        assert!(Task::decode(&b).is_err());
    }

    #[test]
    fn async_task_codec_is_exact_length() {
        // The staleness fields ride fixed 29-byte layouts; every other
        // length — truncation, the sync 21-byte frame under the async
        // tag, trailing bytes — is rejected exactly (PR-3 style: no
        // arithmetic on attacker-controlled counts, just equality).
        let red = Task::Reduce {
            batch_ref: BatchRef { epoch: 1, batch: 2 },
            num_minibatches: 16,
            model_version: 18,
            plan: AggregationPlan::Async { tau: 7 },
        };
        let rb = red.encode();
        assert_eq!(rb.len(), 29);
        assert_eq!(rb[0], 5); // TAG_REDUCE_ASYNC
        // Prefix matches the frozen flat reduce layout byte-for-byte;
        // tau rides behind it.
        let flat = Task::Reduce {
            batch_ref: BatchRef { epoch: 1, batch: 2 },
            num_minibatches: 16,
            model_version: 18,
            plan: AggregationPlan::Flat,
        }
        .encode();
        assert_eq!(&rb[1..21], &flat[1..21]);
        assert_eq!(u64::from_le_bytes(rb[21..29].try_into().unwrap()), 7);
        for cut in [1, 20, 21, 25, 28] {
            assert!(Task::decode(&rb[..cut]).is_err(), "reduce cut {cut}");
        }
        let mut long = rb.clone();
        long.push(0);
        assert!(Task::decode(&long).is_err());
        // Zero minibatches still rejected through the async tag.
        let mut z = rb.clone();
        z[9..13].copy_from_slice(&0u32.to_le_bytes());
        assert!(Task::decode(&z).is_err());

        let map = Task::Map {
            batch_ref: BatchRef { epoch: 1, batch: 2 },
            minibatch: 5,
            model_version: 18,
            staleness: Some(3),
        };
        let mb = map.encode();
        assert_eq!(mb.len(), 29);
        assert_eq!(mb[0], 6); // TAG_MAP_ASYNC
        for cut in [1, 20, 21, 28] {
            assert!(Task::decode(&mb[..cut]).is_err(), "map cut {cut}");
        }
        let mut mlong = mb.clone();
        mlong.push(0);
        assert!(Task::decode(&mlong).is_err());
        // Reserved slot index rejected through the async tag too.
        let mut mm = mb.clone();
        mm[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Task::decode(&mm).is_err());
        // τ = 0 is a legal bound (the barrier degenerate), not garbage.
        let m0 = Task::Map {
            batch_ref: BatchRef { epoch: 0, batch: 0 },
            minibatch: 0,
            model_version: 0,
            staleness: Some(0),
        };
        assert_eq!(Task::decode(&m0.encode()).unwrap(), m0);
    }

    #[test]
    fn grad_result_roundtrip() {
        let leaf = GradResult::leaf(
            BatchRef { epoch: 1, batch: 2 },
            5,
            4.58,
            vec![0.25, -1.5, 3.0],
        );
        assert_eq!(GradResult::decode(&leaf.encode()).unwrap(), leaf);
        // Leaves keep the 20 + 4n legacy layout on the wire.
        assert_eq!(leaf.encode().len(), 20 + 3 * 4);
        let partial = GradResult {
            batch_ref: BatchRef { epoch: 1, batch: 2 },
            slot_lo: 4,
            slot_hi: 8,
            weight: 4,
            loss: 2.0,
            grads: vec![1.0, 2.0],
        };
        assert_eq!(GradResult::decode(&partial.encode()).unwrap(), partial);
        assert_eq!(partial.encode().len(), 36 + 2 * 4);
    }

    #[test]
    fn grad_result_decodes_legacy_payload() {
        // A payload hand-built in the pre-tree wire format must decode as
        // a weight-1 leaf.
        let mut b = Vec::new();
        b.extend_from_slice(&0u32.to_le_bytes()); // epoch
        b.extend_from_slice(&3u32.to_le_bytes()); // batch
        b.extend_from_slice(&7u32.to_le_bytes()); // minibatch
        b.extend_from_slice(&1.5f32.to_le_bytes()); // loss
        b.extend_from_slice(&2u32.to_le_bytes()); // n
        b.extend_from_slice(&0.5f32.to_le_bytes());
        b.extend_from_slice(&(-0.25f32).to_le_bytes());
        let g = GradResult::decode(&b).unwrap();
        assert_eq!(g.batch_ref, BatchRef { epoch: 0, batch: 3 });
        assert_eq!((g.slot_lo, g.slot_hi, g.weight), (7, 8, 1));
        assert!(g.is_leaf());
        assert_eq!(g.grads, vec![0.5, -0.25]);
    }

    #[test]
    fn grad_result_rejects_truncation() {
        let g = GradResult::leaf(BatchRef { epoch: 0, batch: 0 }, 0, 0.0, vec![1.0]);
        let mut b = g.encode();
        b.pop();
        assert!(GradResult::decode(&b).is_err());
        let p = GradResult {
            batch_ref: BatchRef { epoch: 0, batch: 0 },
            slot_lo: 0,
            slot_hi: 2,
            weight: 2,
            loss: 0.0,
            grads: vec![1.0],
        };
        let mut b = p.encode();
        b.pop();
        assert!(GradResult::decode(&b).is_err());
        // Versioned header shorter than its fixed part.
        let mut short = GRAD_MAGIC.to_le_bytes().to_vec();
        short.extend_from_slice(&[0u8; 20]);
        assert!(GradResult::decode(&short).is_err());
    }

    #[test]
    fn grad_result_rejects_adversarial_counts() {
        // A length field claiming a huge element count must fail the
        // division-form guard, not wrap `n * 4` (32-bit usize) into a
        // bogus pass + oversized allocation.
        let mut b = Vec::new();
        b.extend_from_slice(&0u32.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        b.extend_from_slice(&0f32.to_le_bytes());
        b.extend_from_slice(&u32::MAX.to_le_bytes()); // n = 2^32 - 1
        b.extend_from_slice(&[0u8; 4]);
        assert!(GradResult::decode(&b).is_err());
        // Same claim through the versioned layout.
        let mut v = Vec::new();
        v.extend_from_slice(&GRAD_MAGIC.to_le_bytes());
        v.extend_from_slice(&GRAD_VERSION.to_le_bytes());
        v.extend_from_slice(&[0u8; 8]); // epoch, batch
        v.extend_from_slice(&0u32.to_le_bytes()); // slot_lo
        v.extend_from_slice(&2u32.to_le_bytes()); // slot_hi
        v.extend_from_slice(&2u32.to_le_bytes()); // weight
        v.extend_from_slice(&0f32.to_le_bytes()); // loss
        v.extend_from_slice(&0x4000_0001u32.to_le_bytes()); // n * 4 wraps on 32-bit
        v.extend_from_slice(&[0u8; 4]);
        assert!(GradResult::decode(&v).is_err());
        // Inconsistent weight / range claims.
        let mut w = Vec::new();
        w.extend_from_slice(&GRAD_MAGIC.to_le_bytes());
        w.extend_from_slice(&GRAD_VERSION.to_le_bytes());
        w.extend_from_slice(&[0u8; 8]);
        w.extend_from_slice(&4u32.to_le_bytes()); // slot_lo
        w.extend_from_slice(&8u32.to_le_bytes()); // slot_hi
        w.extend_from_slice(&3u32.to_le_bytes()); // weight != 4
        w.extend_from_slice(&0f32.to_le_bytes());
        w.extend_from_slice(&0u32.to_le_bytes());
        assert!(GradResult::decode(&w).is_err());
        // Future versioned format is rejected, not misparsed.
        let mut f = Vec::new();
        f.extend_from_slice(&GRAD_MAGIC.to_le_bytes());
        f.extend_from_slice(&2u32.to_le_bytes());
        f.extend_from_slice(&[0u8; 28]);
        assert!(GradResult::decode(&f).is_err());
    }

    #[test]
    fn task_stage_order() {
        let b = BatchRef { epoch: 0, batch: 0 };
        let map = Task::Map { batch_ref: b, minibatch: 0, model_version: 0, staleness: None };
        let c1 = Task::Combine {
            batch_ref: b,
            level: 1,
            slot_lo: 0,
            slot_hi: 2,
            fanin: 2,
            model_version: 0,
        };
        let red = Task::Reduce {
            batch_ref: b,
            num_minibatches: 4,
            model_version: 0,
            plan: AggregationPlan::Tree { fanin: 2 },
        };
        assert!(map.stage() < c1.stage());
        assert!(c1.stage() < red.stage());
    }

    #[test]
    fn global_index() {
        let b = BatchRef { epoch: 2, batch: 3 };
        assert_eq!(b.global_index(16), 35);
    }
}
