//! Aggregation topologies: how a batch's minibatch gradients are folded
//! into one model update.
//!
//! The paper's protocol is `flat`: one Reduce task serially pulls all k
//! full gradient vectors through one queue and applies the update alone —
//! which is exactly why its own Fig. 6 shows relative efficiency falling
//! below 1 at 32 volunteers (the version barrier is gated on a single
//! volunteer's bandwidth). [`AggregationPlan`] makes the reduction path
//! pluggable:
//!
//! - [`AggregationPlan::Flat`] — the paper-faithful default. The task
//!   stream, priorities, and queue layout are byte-identical to the
//!   original map→single-reduce pipeline (golden-tested in
//!   rust/tests/agg_topology.rs).
//! - [`AggregationPlan::Tree`] — `tree:<fanin>`: `Combine` tasks fold
//!   disjoint slot-ranges of the batch's gradients into partial-sum
//!   [`GradResult`](crate::coordinator::task::GradResult)s on per-level
//!   queues (`results.map.e<e>.b<b>.l<level>`), and the final Reduce
//!   folds only ≤ fanin partials. The busiest single volunteer moves
//!   O(fanin) gradient vectors per step instead of O(k).
//!
//! # Tree shape
//!
//! Deterministic and compiled by the Initiator, never negotiated at run
//! time: the node at level `l` with index `j` covers leaf slots
//! `[j·fanin^l, min((j+1)·fanin^l, k))`. Combine levels run `1..=levels`,
//! where [`AggregationPlan::levels`] is the smallest `L` with
//! `ceil(k / fanin^L) <= fanin`; the Reduce folds the level-`L` nodes.
//! `k <= fanin` degenerates to flat (no combine levels).
//!
//! Fold order is part of the contract: every node folds its children in
//! slot-index order, so a run's final model depends only on the plan
//! shape, never on volunteer scheduling — [`AggregationPlan::oracle_fold`]
//! is the serial oracle of the same shape the property tests compare
//! against.
//!
//! - [`AggregationPlan::Async`] — `async:<tau>`: bounded-staleness
//!   aggregation. Each batch's Reduce applies its folded gradient against
//!   whatever model is current — no version barrier — as long as the
//!   model has advanced at most τ versions past the batch's base version.
//!   Staler-than-τ updates are rejected and their work recycled as fresh
//!   tasks. What a finished gradient *does* to the model is no longer
//!   hard-coded per call site: every variant compiles to an
//!   [`UpdatePolicy`], and the sync plans are exactly the τ=0 degenerate
//!   case ([`UpdatePolicy::BarrierSync`]).
//!
//! A fourth variant (DistML.js-style synchronous allreduce rounds) slots
//! in behind the same types — see ROADMAP.md.

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Result};

/// How a batch's gradients are aggregated into one update.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AggregationPlan {
    /// Paper layout: one Reduce folds all k minibatch gradients.
    Flat,
    /// Hierarchical partial sums: Combine nodes with `fanin` children per
    /// level, final Reduce folds ≤ `fanin` partials. `fanin >= 2`.
    Tree { fanin: u32 },
    /// Bounded-staleness: the flat task layout, but Reduce applies its
    /// update against the *current* model (no version barrier) provided
    /// the model is at most `tau` versions ahead of the batch's base
    /// version. `tau = 0` degenerates to the synchronous barrier.
    Async { tau: u64 },
}

/// How a finished, folded gradient becomes a model update — the seam the
/// agent apply path and the sim release schedule both branch on. Derived
/// from the plan via [`AggregationPlan::update_policy`]; sync plans (flat,
/// tree) are the τ=0 degenerate case.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UpdatePolicy {
    /// Paper semantics: a Reduce pins the exact model version its maps
    /// computed against and waits for it (`await_version`); the update is
    /// the plain optimizer step. Equivalent to `BoundedStaleness` with
    /// τ = 0 plus a wait instead of a reject.
    BarrierSync,
    /// Barrier-free: apply against the current model if its version is at
    /// most `tau` past the update's base version (weighted by version
    /// distance, [`crate::model::merge_update`]); recycle the batch as
    /// fresh tasks otherwise.
    BoundedStaleness { tau: u64 },
}

impl UpdatePolicy {
    /// Whether an update computed against base version `base` may still
    /// be applied when the model is at `current` (`current >= base`).
    /// Under `BarrierSync` only the exact version matches — the barrier
    /// itself guarantees `current == base` on the apply path.
    pub fn admits(&self, base: u64, current: u64) -> bool {
        match self {
            UpdatePolicy::BarrierSync => current == base,
            UpdatePolicy::BoundedStaleness { tau } => current.saturating_sub(base) <= *tau,
        }
    }
}

impl Default for AggregationPlan {
    fn default() -> Self {
        AggregationPlan::Flat
    }
}

impl fmt::Display for AggregationPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AggregationPlan::Flat => write!(f, "flat"),
            AggregationPlan::Tree { fanin } => write!(f, "tree:{fanin}"),
            AggregationPlan::Async { tau } => write!(f, "async:{tau}"),
        }
    }
}

impl FromStr for AggregationPlan {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        if s == "flat" {
            return Ok(AggregationPlan::Flat);
        }
        if let Some(n) = s.strip_prefix("tree:") {
            let fanin: u32 = n
                .parse()
                .map_err(|_| anyhow::anyhow!("bad tree fanin '{n}' in agg plan '{s}'"))?;
            if fanin < 2 {
                bail!("tree fanin must be >= 2, got {fanin}");
            }
            return Ok(AggregationPlan::Tree { fanin });
        }
        if let Some(n) = s.strip_prefix("async:") {
            let tau: u64 = n
                .parse()
                .map_err(|_| anyhow::anyhow!("bad async staleness bound '{n}' in agg plan '{s}'"))?;
            return Ok(AggregationPlan::Async { tau });
        }
        bail!("unknown aggregation plan '{s}' (flat | tree:<fanin> | async:<tau>)")
    }
}

/// Priority stride reserved per batch under a tree plan: room for stage
/// 0 (maps), combine levels 1..=62, and the reduce at 63. With fanin 2 a
/// u32 slot count needs at most 32 levels, so the stride never truncates
/// a real schedule. Flat keeps the historical stride of 2 (maps at
/// `version*2`, reduce at `version*2 + 1`) so the task stream is
/// byte-identical to the original pipeline.
pub const TREE_PRIORITY_STRIDE: u64 = 64;

impl AggregationPlan {
    /// Number of combine levels for a batch of `k` minibatch slots
    /// (0 = the Reduce folds the leaves directly).
    pub fn levels(&self, k: u32) -> u32 {
        match self {
            AggregationPlan::Flat | AggregationPlan::Async { .. } => 0,
            AggregationPlan::Tree { fanin } => {
                let mut l = 0u32;
                let mut count = k.max(1);
                while count > *fanin {
                    l += 1;
                    count = count.div_ceil(*fanin);
                }
                l
            }
        }
    }

    /// Leaf slots covered by one node at `level` (`fanin^level`; 1 at the
    /// leaves). Saturates, which is harmless: a saturated width covers
    /// every slot of any u32-sized batch.
    pub fn node_width(&self, level: u32) -> u64 {
        match self {
            AggregationPlan::Flat | AggregationPlan::Async { .. } => 1,
            AggregationPlan::Tree { fanin } => (*fanin as u64).saturating_pow(level),
        }
    }

    /// The disjoint slot ranges `[lo, hi)` of the nodes at `level`, in
    /// index order (level 0 = the k unit leaf ranges).
    pub fn nodes_at(&self, k: u32, level: u32) -> Vec<(u32, u32)> {
        let w = self.node_width(level);
        let mut out = Vec::new();
        let mut lo = 0u64;
        while lo < k as u64 {
            let hi = (lo + w).min(k as u64);
            out.push((lo as u32, hi as u32));
            lo = hi;
        }
        out
    }

    /// The child ranges (at `level - 1`) of the node covering `[lo, hi)`
    /// at `level >= 1`, in index order. Each node has ≤ fanin children.
    pub fn child_ranges(&self, level: u32, lo: u32, hi: u32) -> Vec<(u32, u32)> {
        debug_assert!(level >= 1);
        let w = self.node_width(level - 1);
        let mut out = Vec::new();
        let mut a = lo as u64;
        while a < hi as u64 {
            let b = (a + w).min(hi as u64);
            out.push((a as u32, b as u32));
            a = b;
        }
        out
    }

    /// Ranges the final Reduce of a k-slot batch folds (the top level's
    /// nodes; for flat, the k unit leaf ranges).
    pub fn reduce_ranges(&self, k: u32) -> Vec<(u32, u32)> {
        self.nodes_at(k, self.levels(k))
    }

    /// Every node of the subtree rooted at the `level` node covering
    /// `[lo, hi)`, as (level, lo, hi) triples — the leaves (level 0) and
    /// the root included. This is the full set of tasks that can
    /// regenerate the range's partial sum from the corpus: poison
    /// recovery republishes all of them, because a combine ACKs its
    /// inputs away once its output is published, so republishing the
    /// root combine alone could never refill (agent.rs).
    pub fn subtree(&self, level: u32, lo: u32, hi: u32) -> Vec<(u32, u32, u32)> {
        let mut out = Vec::new();
        for l in 0..=level {
            let w = self.node_width(l);
            let mut a = lo as u64;
            while a < hi as u64 {
                let b = (a + w).min(hi as u64);
                out.push((l, a as u32, b as u32));
                a = b;
            }
        }
        out
    }

    /// Batch-priority stride: how many priority slots one batch occupies
    /// in the task queue.
    pub fn stride(&self) -> u64 {
        match self {
            // Async keeps the flat stride: it has no combine levels, and
            // sharing the scheme keeps τ=0 streams byte-identical to flat.
            AggregationPlan::Flat | AggregationPlan::Async { .. } => 2,
            AggregationPlan::Tree { .. } => TREE_PRIORITY_STRIDE,
        }
    }

    /// The update policy this plan compiles to: the one seam deciding how
    /// a finished gradient becomes a model update (agent apply path, sim
    /// release schedule, oracle fold).
    pub fn update_policy(&self) -> UpdatePolicy {
        match self {
            AggregationPlan::Flat | AggregationPlan::Tree { .. } => UpdatePolicy::BarrierSync,
            AggregationPlan::Async { tau } => UpdatePolicy::BoundedStaleness { tau: *tau },
        }
    }

    /// Queue priority for a task of `version` at `stage` (0 = maps,
    /// l = combine level l, `u32::MAX` = reduce): batch order first, then
    /// stage order within the batch — level-l combines strictly precede
    /// level-(l+1), and the reduce comes last. This is the total order
    /// the deadlock-freedom argument in coordinator/mod.rs rests on.
    pub fn task_priority(&self, version: u64, stage: u32) -> u64 {
        let stride = self.stride();
        version * stride + (stage as u64).min(stride - 1)
    }

    /// Serial oracle of this plan's fold shape: node sums computed in
    /// slot-index order at every level, final mean over the top-level
    /// partials. For [`AggregationPlan::Flat`] this is bit-identical to
    /// [`GradAccumulator::fold`](crate::model::GradAccumulator::fold) —
    /// sum the k leaves in index order, multiply by `1/k as f32`.
    pub fn oracle_fold(&self, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        let k = grads.len() as u32;
        if k == 0 {
            bail!("oracle_fold needs at least one gradient");
        }
        let n = grads[0].len();
        for g in grads {
            if g.len() != n {
                bail!("gradient length mismatch");
            }
        }
        // Sum of the node covering [lo, hi) at `level`, children folded
        // in index order — the same add sequence every Combine performs
        // (zero-initialized accumulator, exactly like
        // `GradAccumulator::fold_sum`, so even signed zeros match).
        fn node_sum(
            plan: &AggregationPlan,
            grads: &[Vec<f32>],
            level: u32,
            lo: u32,
            hi: u32,
        ) -> Vec<f32> {
            if level == 0 {
                return grads[lo as usize].clone();
            }
            let n = grads[0].len();
            let mut acc = vec![0.0f32; n];
            for (clo, chi) in plan.child_ranges(level, lo, hi) {
                let child = node_sum(plan, grads, level - 1, clo, chi);
                for (x, y) in acc.iter_mut().zip(child.iter()) {
                    *x += y;
                }
            }
            acc
        }
        let top = self.levels(k);
        let mut acc = vec![0.0f32; n];
        for (lo, hi) in self.nodes_at(k, top) {
            let s = node_sum(self, grads, top, lo, hi);
            for (a, b) in acc.iter_mut().zip(s.iter()) {
                *a += b;
            }
        }
        let inv = 1.0f32 / k as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        assert_eq!("flat".parse::<AggregationPlan>().unwrap(), AggregationPlan::Flat);
        assert_eq!(
            "tree:4".parse::<AggregationPlan>().unwrap(),
            AggregationPlan::Tree { fanin: 4 }
        );
        assert_eq!(AggregationPlan::Tree { fanin: 3 }.to_string(), "tree:3");
        assert_eq!(AggregationPlan::Flat.to_string(), "flat");
        assert!("tree:1".parse::<AggregationPlan>().is_err());
        assert!("tree:".parse::<AggregationPlan>().is_err());
        assert!("ring".parse::<AggregationPlan>().is_err());
        assert_eq!(
            "async:4".parse::<AggregationPlan>().unwrap(),
            AggregationPlan::Async { tau: 4 }
        );
        assert_eq!(
            "async:0".parse::<AggregationPlan>().unwrap(),
            AggregationPlan::Async { tau: 0 }
        );
        assert_eq!(AggregationPlan::Async { tau: 16 }.to_string(), "async:16");
        assert!("async:".parse::<AggregationPlan>().is_err());
        assert!("async:-1".parse::<AggregationPlan>().is_err());
        assert!("async".parse::<AggregationPlan>().is_err());
    }

    #[test]
    fn async_keeps_the_flat_task_scheme() {
        // async:<τ> has no combine levels and shares flat's priority
        // stride, so its task stream shape is flat's exactly — only the
        // reduce tag and apply semantics differ.
        let a = AggregationPlan::Async { tau: 3 };
        let f = AggregationPlan::Flat;
        assert_eq!(a.levels(16), 0);
        assert_eq!(a.stride(), f.stride());
        for v in [0u64, 7] {
            assert_eq!(a.task_priority(v, 0), f.task_priority(v, 0));
            assert_eq!(a.task_priority(v, u32::MAX), f.task_priority(v, u32::MAX));
        }
        assert_eq!(a.reduce_ranges(5), f.reduce_ranges(5));
        assert_eq!(a.subtree(0, 3, 4), f.subtree(0, 3, 4));
    }

    #[test]
    fn update_policy_degenerates_at_tau_zero() {
        assert_eq!(AggregationPlan::Flat.update_policy(), UpdatePolicy::BarrierSync);
        assert_eq!(
            AggregationPlan::Tree { fanin: 4 }.update_policy(),
            UpdatePolicy::BarrierSync
        );
        let p0 = AggregationPlan::Async { tau: 0 }.update_policy();
        assert_eq!(p0, UpdatePolicy::BoundedStaleness { tau: 0 });
        // τ=0 admits exactly what the barrier admits.
        for (base, cur) in [(0u64, 0u64), (3, 3), (3, 4), (0, 10)] {
            assert_eq!(p0.admits(base, cur), UpdatePolicy::BarrierSync.admits(base, cur));
        }
        let p2 = UpdatePolicy::BoundedStaleness { tau: 2 };
        assert!(p2.admits(5, 5) && p2.admits(5, 7));
        assert!(!p2.admits(5, 8));
        // current < base (concurrent publish raced us) never underflows.
        assert!(p2.admits(5, 3));
    }

    #[test]
    fn oracle_fold_async_matches_flat() {
        let grads: Vec<Vec<f32>> =
            (0..5).map(|i| vec![i as f32 * 0.3 + 0.1, -(i as f32) * 0.7]).collect();
        assert_eq!(
            AggregationPlan::Async { tau: 4 }.oracle_fold(&grads).unwrap(),
            AggregationPlan::Flat.oracle_fold(&grads).unwrap()
        );
    }

    #[test]
    fn levels_match_fanin() {
        let t4 = AggregationPlan::Tree { fanin: 4 };
        assert_eq!(t4.levels(16), 1); // 16 -> 4 nodes <= fanin
        assert_eq!(t4.levels(4), 0); // k <= fanin: flat-degenerate
        assert_eq!(t4.levels(17), 2); // 17 -> 5 -> 2
        let t2 = AggregationPlan::Tree { fanin: 2 };
        assert_eq!(t2.levels(16), 3); // 16 -> 8 -> 4 -> 2
        assert_eq!(t2.levels(2), 0);
        assert_eq!(AggregationPlan::Flat.levels(16), 0);
    }

    #[test]
    fn nodes_and_children_partition() {
        let t = AggregationPlan::Tree { fanin: 4 };
        assert_eq!(t.nodes_at(16, 1), vec![(0, 4), (4, 8), (8, 12), (12, 16)]);
        // Ragged tail: 10 slots, fanin 4.
        assert_eq!(t.nodes_at(10, 1), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(t.child_ranges(1, 8, 10), vec![(8, 9), (9, 10)]);
        let t2 = AggregationPlan::Tree { fanin: 2 };
        assert_eq!(t2.nodes_at(16, 3), vec![(0, 8), (8, 16)]);
        assert_eq!(t2.child_ranges(3, 8, 16), vec![(8, 12), (12, 16)]);
        // Every level's nodes partition [0, k).
        for k in [1u32, 2, 5, 16, 17, 33] {
            for fanin in [2u32, 3, 4, 8] {
                let p = AggregationPlan::Tree { fanin };
                for level in 0..=p.levels(k) {
                    let nodes = p.nodes_at(k, level);
                    let mut expect = 0u32;
                    for (lo, hi) in &nodes {
                        assert_eq!(*lo, expect);
                        assert!(hi > lo);
                        expect = *hi;
                    }
                    assert_eq!(expect, k);
                    if level >= 1 {
                        for (lo, hi) in nodes {
                            let kids = p.child_ranges(level, lo, hi);
                            assert!(kids.len() <= fanin as usize);
                            assert_eq!(kids.first().unwrap().0, lo);
                            assert_eq!(kids.last().unwrap().1, hi);
                        }
                    }
                }
                // The reduce folds at most fanin partials.
                assert!(p.reduce_ranges(k).len() <= fanin as usize);
            }
        }
    }

    #[test]
    fn subtree_reaches_the_leaves() {
        let t2 = AggregationPlan::Tree { fanin: 2 };
        // Root [4, 8) at level 2: its 2 level-1 children, its 4 leaves,
        // and itself — every task poison recovery must republish.
        assert_eq!(
            t2.subtree(2, 4, 8),
            vec![(0, 4, 5), (0, 5, 6), (0, 6, 7), (0, 7, 8), (1, 4, 6), (1, 6, 8), (2, 4, 8)]
        );
        // Level 0 root (flat reduce's missing leaf): just the map.
        assert_eq!(t2.subtree(0, 3, 4), vec![(0, 3, 4)]);
        // Ragged tail keeps its true bounds.
        let t4 = AggregationPlan::Tree { fanin: 4 };
        assert_eq!(t4.subtree(1, 8, 10), vec![(0, 8, 9), (0, 9, 10), (1, 8, 10)]);
    }

    #[test]
    fn flat_priorities_are_the_historical_scheme() {
        let p = AggregationPlan::Flat;
        assert_eq!(p.task_priority(0, 0), 0);
        assert_eq!(p.task_priority(0, u32::MAX), 1);
        assert_eq!(p.task_priority(7, 0), 14);
        assert_eq!(p.task_priority(7, u32::MAX), 15);
    }

    #[test]
    fn tree_priorities_order_stages_within_a_batch() {
        let p = AggregationPlan::Tree { fanin: 2 };
        let v = 3u64;
        let map = p.task_priority(v, 0);
        let c1 = p.task_priority(v, 1);
        let c2 = p.task_priority(v, 2);
        let red = p.task_priority(v, u32::MAX);
        assert!(map < c1 && c1 < c2 && c2 < red);
        // Everything of batch v precedes everything of batch v+1.
        assert!(red < p.task_priority(v + 1, 0));
    }

    #[test]
    fn oracle_fold_flat_matches_accumulator() {
        use crate::model::GradAccumulator;
        let grads: Vec<Vec<f32>> =
            (0..5).map(|i| vec![i as f32 * 0.3 + 0.1, -(i as f32) * 0.7]).collect();
        let mut acc = GradAccumulator::new(5);
        for (i, g) in grads.iter().enumerate() {
            acc.insert(i, g.clone()).unwrap();
        }
        assert_eq!(
            AggregationPlan::Flat.oracle_fold(&grads).unwrap(),
            acc.fold().unwrap()
        );
    }

    #[test]
    fn oracle_fold_shapes_agree_on_exact_sums() {
        // Integer-valued gradients sum exactly in any association, so
        // every plan shape must produce the same mean.
        let grads: Vec<Vec<f32>> = (0..16).map(|i| vec![(i % 7) as f32 - 3.0, i as f32]).collect();
        let flat = AggregationPlan::Flat.oracle_fold(&grads).unwrap();
        for fanin in [2u32, 3, 4, 8] {
            let tree = AggregationPlan::Tree { fanin }.oracle_fold(&grads).unwrap();
            assert_eq!(flat, tree, "fanin {fanin}");
        }
    }
}
