//! Model-version synchronization protocol (S5, paper §IV.G):
//!
//! "The NN model is stored and shared in the DataServer, and it is updated
//! after each reduce task. The NN model has an ID identifying the model
//! version. Each map task has an ID that identifies the version of the
//! model to which the calculation of the gradients is to be made. If the
//! required version is not yet available, the task waits."
//!
//! Thin, typed wrappers over [`DataApi`] keeping the snapshot codec and
//! key names in one place.

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::keys;
use crate::data::DataApi;
use crate::model::ModelSnapshot;

/// Publish model version `snapshot.version` (idempotent: versions only
/// move forward, so duplicate reduce executions are harmless).
pub fn publish_model(data: &dyn DataApi, snapshot: &ModelSnapshot) -> Result<()> {
    data.put_versioned(keys::MODEL, snapshot.version, &snapshot.to_bytes())
}

/// Current model version, if any.
pub fn current_version(data: &dyn DataApi) -> Result<Option<u64>> {
    Ok(data.get_versioned(keys::MODEL)?.map(|v| v.version))
}

/// Fetch the newest snapshot.
pub fn get_model(data: &dyn DataApi) -> Result<Option<ModelSnapshot>> {
    match data.get_versioned(keys::MODEL)? {
        Some(v) => Ok(Some(ModelSnapshot::from_bytes(&v.bytes)?)),
        None => Ok(None),
    }
}

/// Block until the model reaches at least `version` (the map-task wait).
/// Returns the snapshot actually stored (its version may be newer; the
/// caller decides whether that matters — for gradient computation the
/// paper pins the exact version, so [`wait_exact_model`] checks).
pub fn wait_model(
    data: &dyn DataApi,
    version: u64,
    timeout: Duration,
) -> Result<Option<ModelSnapshot>> {
    match data.wait_version(keys::MODEL, version, timeout)? {
        Some(v) => Ok(Some(ModelSnapshot::from_bytes(&v.bytes)?)),
        None => Ok(None),
    }
}

/// Wait for exactly `version`; errors if the server has already advanced
/// past it (the task is stale — its batch was completed by someone else,
/// which can only happen after duplicate delivery).
pub fn wait_exact_model(
    data: &dyn DataApi,
    version: u64,
    timeout: Duration,
) -> Result<Option<ModelSnapshot>> {
    match wait_model(data, version, timeout)? {
        None => Ok(None),
        Some(s) if s.version == version => Ok(Some(s)),
        Some(s) => Err(anyhow!(
            "model advanced past v{version} (at v{}): task is stale",
            s.version
        )),
    }
}

/// Cooperative stop flag (classroom scenario 3: volunteers dismissed).
pub fn request_stop(data: &dyn DataApi) -> Result<()> {
    data.put(keys::STOP, &[1])
}

pub fn stop_requested(data: &dyn DataApi) -> Result<bool> {
    Ok(data.get(keys::STOP)?.map(|v| v == [1]).unwrap_or(false))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Store;

    #[test]
    fn publish_and_wait() {
        let s = Store::new();
        assert_eq!(current_version(&s).unwrap(), None);
        let snap = ModelSnapshot { version: 0, params: vec![1.0], ms: vec![0.0] };
        publish_model(&s, &snap).unwrap();
        assert_eq!(current_version(&s).unwrap(), Some(0));
        let got = wait_model(&s, 0, Duration::from_millis(5)).unwrap().unwrap();
        assert_eq!(got, snap);
        assert!(wait_model(&s, 1, Duration::from_millis(5)).unwrap().is_none());
    }

    #[test]
    fn stale_version_detected() {
        let s = Store::new();
        publish_model(&s, &ModelSnapshot { version: 7, params: vec![], ms: vec![] }).unwrap();
        assert!(wait_exact_model(&s, 7, Duration::from_millis(5)).unwrap().is_some());
        assert!(wait_exact_model(&s, 3, Duration::from_millis(5)).is_err());
    }

    #[test]
    fn duplicate_publish_keeps_newest() {
        let s = Store::new();
        publish_model(&s, &ModelSnapshot { version: 2, params: vec![2.0], ms: vec![0.0] }).unwrap();
        publish_model(&s, &ModelSnapshot { version: 1, params: vec![1.0], ms: vec![0.0] }).unwrap();
        let got = get_model(&s).unwrap().unwrap();
        assert_eq!(got.version, 2);
    }

    #[test]
    fn stop_flag() {
        let s = Store::new();
        assert!(!stop_requested(&s).unwrap());
        request_stop(&s).unwrap();
        assert!(stop_requested(&s).unwrap());
    }
}
