//! The JSDoop coordination layer (S3-S5): problem setup (Initiator),
//! execution flow over queues, and the model-version synchronization
//! protocol of paper §IV.G.
//!
//! Layout of the distributed training problem (paper Fig 3):
//!
//! ```text
//!  tasks            = [ map(b0,0..16), reduce(b0), map(b1,0..16), ... ]   FIFO
//!  results.map.<b>  = gradients published by map tasks of batch b
//!  DataServer: "problem" (spec), "corpus", "model" (versioned snapshot)
//! ```
//!
//! Both task kinds share ONE FIFO queue, exactly like the paper's
//! `InitialQueue`: with in-order consumption this guarantees the reduce of
//! batch k is claimed before any map of batch k+1, which (together with
//! redelivery-to-front) makes the protocol deadlock-free for any number of
//! volunteers >= 1 (proved by the property tests).

pub mod initiator;
pub mod task;
pub mod version;

use anyhow::{bail, Result};

use crate::textdata::Schedule;

/// Queue names (paper §IV.D: "different specialized queues").
pub mod queues {
    use super::task::BatchRef;

    /// The InitialQueue: interleaved map + reduce tasks.
    pub const TASKS: &str = "tasks";

    /// MapResultsQueue, one per batch so a slow straggler from batch k
    /// can never contaminate batch k+1.
    pub fn map_results(b: BatchRef) -> String {
        format!("results.map.e{}.b{}", b.epoch, b.batch)
    }
}

/// DataServer keys.
pub mod keys {
    /// Versioned model snapshot (the parameter server).
    pub const MODEL: &str = "model";
    /// Encoded corpus blob.
    pub const CORPUS: &str = "corpus";
    /// Encoded [`ProblemSpec`].
    pub const PROBLEM: &str = "problem";
    /// Cooperative stop flag (volunteers poll it between tasks).
    pub const STOP: &str = "stop";
    /// Progress counter: completed reduce tasks.
    pub const REDUCES_DONE: &str = "ctr.reduces";
}

/// Everything a volunteer needs to know about the problem — the stand-in
/// for the JavaScript the paper's WebServer ships to the browser.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProblemSpec {
    pub schedule: Schedule,
    pub learning_rate: f32,
}

impl ProblemSpec {
    pub fn total_versions(&self) -> u64 {
        self.schedule.total_batches() as u64
    }

    pub fn encode(&self) -> Vec<u8> {
        let s = &self.schedule;
        let mut b = Vec::with_capacity(44);
        for v in [
            s.seq_len as u64,
            s.batch_size as u64,
            s.minibatch_size as u64,
            s.examples_per_epoch as u64,
            s.epochs as u64,
        ] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&self.learning_rate.to_le_bytes());
        b
    }

    pub fn decode(b: &[u8]) -> Result<Self> {
        if b.len() != 44 {
            bail!("problem spec must be 44 bytes, got {}", b.len());
        }
        let u = |i: usize| u64::from_le_bytes(b[i..i + 8].try_into().unwrap()) as usize;
        let spec = ProblemSpec {
            schedule: Schedule {
                seq_len: u(0),
                batch_size: u(8),
                minibatch_size: u(16),
                examples_per_epoch: u(24),
                epochs: u(32),
            },
            learning_rate: f32::from_le_bytes(b[40..44].try_into().unwrap()),
        };
        spec.schedule.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_spec_roundtrip() {
        let spec = ProblemSpec { schedule: Schedule::paper(), learning_rate: 0.1 };
        let d = ProblemSpec::decode(&spec.encode()).unwrap();
        assert_eq!(d, spec);
        assert_eq!(d.total_versions(), 80);
    }

    #[test]
    fn problem_spec_rejects_bad() {
        assert!(ProblemSpec::decode(&[0; 10]).is_err());
        let mut spec = ProblemSpec { schedule: Schedule::paper(), learning_rate: 0.1 };
        spec.schedule.minibatch_size = 3; // doesn't divide 128
        assert!(ProblemSpec::decode(&spec.encode()).is_err());
    }
}
