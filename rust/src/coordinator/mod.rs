//! The JSDoop coordination layer (S3-S5): problem setup (Initiator),
//! execution flow over queues, and the model-version synchronization
//! protocol of paper §IV.G.
//!
//! Layout of the distributed training problem (paper Fig 3), under the
//! default `flat` aggregation plan:
//!
//! ```text
//!  tasks            = [ map(b0,0..16), reduce(b0), map(b1,0..16), ... ]   FIFO
//!  results.map.<b>  = gradients published by map tasks of batch b
//!  DataServer: "problem" (spec), "corpus", "model" (versioned snapshot)
//! ```
//!
//! Under `tree:<fanin>` (see [`agg::AggregationPlan`]) each batch
//! additionally gets one results queue per combine level, and the task
//! stream interleaves the combine stages between the maps and the reduce:
//!
//! ```text
//!  tasks                 = [ map(b0,0..16),
//!                            combine(b0, l1, [0,4)) .. combine(b0, l1, [12,16)),
//!                            reduce(b0),                      # folds 4 partials
//!                            map(b1,0..16), ... ]
//!  results.map.e<e>.b<b>      = leaf gradients (level 0; name unchanged)
//!  results.map.e<e>.b<b>.l<k> = partial sums published by level-k combines
//! ```
//!
//! On a multi-tenant fleet every name above additionally rides behind a
//! job prefix (see `queue/job.rs` — the namespace lives INSIDE the name,
//! so nothing else about the layout changes):
//!
//! ```text
//!  <job>/tasks                      = that job's InitialQueue
//!  <job>/results.map.e<e>.b<b>      = its per-batch leaf gradients
//!  <job>/results.map.e<e>.b<b>.l<k> = its tree-combine partials
//!  DataServer: "<job>/problem", "<job>/corpus", "<job>/model", ...
//! ```
//!
//! A single-job deployment keeps the bare names, byte-identical on the
//! wire and in the WAL to every build before jobs existed.
//!
//! All task kinds share ONE priority queue, exactly like the paper's
//! `InitialQueue`. Priorities encode a TOTAL order — batch first, then
//! stage within the batch (maps < level-1 combines < level-2 combines <
//! ... < reduce; see [`agg::AggregationPlan::task_priority`]) — and
//! NACK/redelivery returns a task to its original slot, so the queue head
//! is always the globally earliest outstanding task. Deadlock freedom for
//! any number of volunteers >= 1 follows by induction on that order: a
//! task at stage s of batch v can only wait on results produced by tasks
//! strictly earlier in the order (maps wait on version v, which batch
//! v-1's reduce publishes; a level-k combine waits on level-(k-1) results
//! of its own slot-range; the reduce waits on top-level partials), and a
//! volunteer parked on a later task periodically probes the head and
//! trades its held task for any strictly-earlier one (the priority-swap /
//! inline-steal rule in volunteer/agent.rs) — so the earliest unfinished
//! task always finds a runner, exactly as in the proved two-stage case
//! (property-tested for both plans in rust/tests/).
//!
//! The barrier-free `async:<tau>` plan KEEPS that total order (its task
//! stream and priorities are the flat layout, so the queue head is still
//! the earliest outstanding task) but weakens what "waiting" means, and
//! the deadlock argument extends rather than breaks: an async map waits
//! only for the version floor `v - tau` — a weaker condition than the
//! sync barrier, satisfied whenever the barrier would be — and an async
//! reduce waits for nothing but its own batch's leaves, which the maps
//! it follows in the order produce. The one NEW wait async introduces is
//! the apply turnstile (volunteer/agent.rs), and it is acquired only
//! AFTER a reduce's inputs are fully collected, strictly in ticket
//! order, with each holder guaranteed to release it on every exit path
//! — so turnstile waits form a chain, never a cycle, and the earliest
//! unfinished task still always finds a runner. Rejected-and-recycled
//! updates re-enter the stream at their original priority, which keeps
//! the head order intact under recycling too.

pub mod agg;
pub mod initiator;
pub mod task;
pub mod version;

use anyhow::{bail, Result};

use crate::textdata::Schedule;

/// Queue names (paper §IV.D: "different specialized queues").
pub mod queues {
    use super::task::BatchRef;

    /// The InitialQueue: interleaved map + reduce tasks.
    pub const TASKS: &str = "tasks";

    /// MapResultsQueue, one per batch so a slow straggler from batch k
    /// can never contaminate batch k+1.
    pub fn map_results(b: BatchRef) -> String {
        format!("results.map.e{}.b{}", b.epoch, b.batch)
    }

    /// Results queue for aggregation `level` of a batch: level 0 is the
    /// leaf queue ([`map_results`], name unchanged so the flat layout is
    /// byte-identical to the paper's); level k >= 1 holds the partial
    /// sums published by level-k combine tasks.
    pub fn agg_results(b: BatchRef, level: u32) -> String {
        if level == 0 {
            map_results(b)
        } else {
            format!("results.map.e{}.b{}.l{}", b.epoch, b.batch, level)
        }
    }
}

/// DataServer keys.
pub mod keys {
    /// Versioned model snapshot (the parameter server).
    pub const MODEL: &str = "model";
    /// Encoded corpus blob.
    pub const CORPUS: &str = "corpus";
    /// Encoded [`ProblemSpec`].
    pub const PROBLEM: &str = "problem";
    /// Cooperative stop flag (volunteers poll it between tasks).
    pub const STOP: &str = "stop";
    /// Progress counter: completed reduce tasks.
    pub const REDUCES_DONE: &str = "ctr.reduces";
    /// Ticket counter for the `async:<tau>` apply turnstile: each
    /// async reduce draws a ticket here after collecting its inputs.
    pub const ASYNC_APPLY_TICKETS: &str = "ctr.async.tickets";
    /// Versioned turnstile key: ticket t applies (or recycles) once
    /// version t-1 is published here, then publishes version t —
    /// serializing model applies so none are lost to the
    /// drop-same-version rule of `put_versioned`.
    pub const ASYNC_APPLY_TURNSTILE: &str = "async.turnstile";
}

/// Everything a volunteer needs to know about the problem — the stand-in
/// for the JavaScript the paper's WebServer ships to the browser.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProblemSpec {
    pub schedule: Schedule,
    pub learning_rate: f32,
}

impl ProblemSpec {
    pub fn total_versions(&self) -> u64 {
        self.schedule.total_batches() as u64
    }

    pub fn encode(&self) -> Vec<u8> {
        let s = &self.schedule;
        let mut b = Vec::with_capacity(44);
        for v in [
            s.seq_len as u64,
            s.batch_size as u64,
            s.minibatch_size as u64,
            s.examples_per_epoch as u64,
            s.epochs as u64,
        ] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&self.learning_rate.to_le_bytes());
        b
    }

    pub fn decode(b: &[u8]) -> Result<Self> {
        if b.len() != 44 {
            bail!("problem spec must be 44 bytes, got {}", b.len());
        }
        let u = |i: usize| u64::from_le_bytes(b[i..i + 8].try_into().unwrap()) as usize;
        let spec = ProblemSpec {
            schedule: Schedule {
                seq_len: u(0),
                batch_size: u(8),
                minibatch_size: u(16),
                examples_per_epoch: u(24),
                epochs: u(32),
            },
            learning_rate: f32::from_le_bytes(b[40..44].try_into().unwrap()),
        };
        spec.schedule.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_spec_roundtrip() {
        let spec = ProblemSpec { schedule: Schedule::paper(), learning_rate: 0.1 };
        let d = ProblemSpec::decode(&spec.encode()).unwrap();
        assert_eq!(d, spec);
        assert_eq!(d.total_versions(), 80);
    }

    #[test]
    fn problem_spec_rejects_bad() {
        assert!(ProblemSpec::decode(&[0; 10]).is_err());
        let mut spec = ProblemSpec { schedule: Schedule::paper(), learning_rate: 0.1 };
        spec.schedule.minibatch_size = 3; // doesn't divide 128
        assert!(ProblemSpec::decode(&spec.encode()).is_err());
    }
}
