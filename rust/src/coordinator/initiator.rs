//! The Initiator (S3, paper §IV.B + §IV.F steps 0-1): configures the
//! DataServer, divides the problem into map/reduce tasks, and uploads them
//! to the QueueServer. "From then on, the Initiator does not participate
//! again in the solution of the problem."

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::agg::AggregationPlan;
use crate::coordinator::task::{BatchRef, Task};
use crate::coordinator::version::publish_model;
use crate::coordinator::{keys, queues, ProblemSpec};
use crate::data::DataApi;
use crate::model::ModelSnapshot;
use crate::queue::job::{JobData, JobQueue, JobQueueApi};
use crate::queue::QueueApi;
use crate::textdata::Corpus;

/// Result of problem setup (for logging / asserts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SetupSummary {
    pub map_tasks: usize,
    pub combine_tasks: usize,
    pub reduce_tasks: usize,
    pub total_versions: u64,
}

/// [`setup_problem_with`] under the paper-faithful flat plan: the task
/// stream, priorities, and queue layout this publishes are byte-identical
/// to the original pipeline (golden-tested in rust/tests/agg_topology.rs).
pub fn setup_problem(
    queue: &dyn QueueApi,
    data: &dyn DataApi,
    spec: &ProblemSpec,
    corpus: &Corpus,
    init_params: Vec<f32>,
) -> Result<SetupSummary> {
    setup_problem_with(queue, data, spec, corpus, init_params, AggregationPlan::Flat)
}

/// Step 0-1: upload corpus + initial model + spec to the DataServer,
/// declare all queues, compile `plan` into the task stream, and enqueue
/// every task in batch order — maps of batch k, then (tree plans) its
/// combine levels bottom-up, then its reduce: the paper's InitialQueue
/// layout, extended with the plan's combine stages.
pub fn setup_problem_with(
    queue: &dyn QueueApi,
    data: &dyn DataApi,
    spec: &ProblemSpec,
    corpus: &Corpus,
    init_params: Vec<f32>,
    plan: AggregationPlan,
) -> Result<SetupSummary> {
    spec.schedule.validate()?;
    if corpus.len() < spec.schedule.seq_len + 2 {
        bail!("corpus shorter than one sample");
    }

    // DataServer: problem descriptor, corpus, model v0.
    data.put(keys::PROBLEM, &spec.encode())?;
    data.put(keys::CORPUS, &corpus.to_bytes())?;
    data.del(keys::STOP)?;
    publish_model(data, &ModelSnapshot::initial(init_params))?;

    // QueueServer: the InitialQueue + the per-level results queues of
    // every batch (level 0 always; levels 1..=L under a tree plan).
    queue.declare(queues::TASKS)?;

    let s = &spec.schedule;
    let k = s.minibatches_per_batch() as u32;
    let top = plan.levels(k);
    let mut map_tasks = 0usize;
    let mut combine_tasks = 0usize;
    let mut reduce_tasks = 0usize;
    for epoch in 0..s.epochs as u32 {
        for batch in 0..s.batches_per_epoch() as u32 {
            let bref = BatchRef { epoch, batch };
            let version = bref.global_index(s.batches_per_epoch() as u32);
            for level in 0..=top {
                queue.declare(&queues::agg_results(bref, level))?;
            }
            // Priority = batch order, stage order within the batch (maps,
            // then combine levels bottom-up, then the reduce): the queue
            // serves the earliest outstanding work first no matter how
            // tasks re-enter it (redelivery, hand-back) — the
            // deadlock-freedom backbone, see coordinator/mod.rs.
            // Async maps carry the staleness bound so volunteers know to
            // skip the exact-version pin; sync maps stay the frozen
            // 21-byte layout.
            let staleness = match plan {
                AggregationPlan::Async { tau } => Some(tau),
                AggregationPlan::Flat | AggregationPlan::Tree { .. } => None,
            };
            for minibatch in 0..k {
                let t = Task::Map { batch_ref: bref, minibatch, model_version: version, staleness };
                queue.publish_pri(queues::TASKS, &t.encode(), plan.task_priority(version, 0))?;
                map_tasks += 1;
            }
            if let AggregationPlan::Tree { fanin } = plan {
                for level in 1..=top {
                    for (slot_lo, slot_hi) in plan.nodes_at(k, level) {
                        let t = Task::Combine {
                            batch_ref: bref,
                            level,
                            slot_lo,
                            slot_hi,
                            fanin,
                            model_version: version,
                        };
                        queue.publish_pri(
                            queues::TASKS,
                            &t.encode(),
                            plan.task_priority(version, level),
                        )?;
                        combine_tasks += 1;
                    }
                }
            }
            let t = Task::Reduce {
                batch_ref: bref,
                num_minibatches: k,
                model_version: version,
                plan,
            };
            queue.publish_pri(
                queues::TASKS,
                &t.encode(),
                plan.task_priority(version, u32::MAX),
            )?;
            reduce_tasks += 1;
        }
    }
    Ok(SetupSummary {
        map_tasks,
        combine_tasks,
        reduce_tasks,
        total_versions: spec.total_versions(),
    })
}

/// [`setup_problem_with`] inside a job (tenant) namespace: every queue
/// and every DataServer key rides behind a `"<job>/"` prefix via the
/// [`JobQueue`]/[`JobData`] views, so N problems share one fleet without
/// touching each other's state. The task stream, priorities, and
/// per-batch layout are IDENTICAL to the single-job setup — multi-tenancy
/// is a deployment decision, not a different protocol.
pub fn setup_problem_job(
    job: &str,
    queue: Arc<dyn JobQueueApi>,
    data: Arc<dyn DataApi>,
    spec: &ProblemSpec,
    corpus: &Corpus,
    init_params: Vec<f32>,
    plan: AggregationPlan,
) -> Result<SetupSummary> {
    let q = JobQueue::new(job, queue)?;
    let d = JobData::new(job, data)?;
    setup_problem_with(&q, &d, spec, corpus, init_params, plan)
}

/// Fetch the problem + corpus a volunteer needs (§IV.F step 2: "a program
/// is executed in background" — this is its bootstrap).
pub fn fetch_problem(data: &dyn DataApi) -> Result<(ProblemSpec, Corpus)> {
    let spec_bytes = data
        .get(keys::PROBLEM)?
        .ok_or_else(|| anyhow::anyhow!("no problem published"))?;
    let spec = ProblemSpec::decode(&spec_bytes)?;
    let corpus_bytes = data
        .get(keys::CORPUS)?
        .ok_or_else(|| anyhow::anyhow!("no corpus published"))?;
    let corpus = Corpus::from_bytes(&corpus_bytes)?;
    Ok((spec, corpus))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Store;
    use crate::queue::broker::Broker;
    use crate::queue::QueueApi;
    use crate::textdata::Schedule;
    use std::time::Duration;

    fn tiny_setup() -> (Broker, Store, SetupSummary) {
        let broker = Broker::with_default_timeout();
        let store = Store::new();
        let spec = ProblemSpec { schedule: Schedule::tiny(), learning_rate: 0.1 };
        let corpus = Corpus::synthetic_js(1, 2000);
        let summary =
            setup_problem(&broker, &store, &spec, &corpus, vec![0.0; 16]).unwrap();
        (broker, store, summary)
    }

    #[test]
    fn setup_counts_match_schedule() {
        let (broker, _store, summary) = tiny_setup();
        // tiny: 32 examples / 16 batch = 2 batches/epoch, 1 epoch,
        // 16/8 = 2 minibatches per batch.
        assert_eq!(summary.map_tasks, 4);
        assert_eq!(summary.combine_tasks, 0);
        assert_eq!(summary.reduce_tasks, 2);
        assert_eq!(summary.total_versions, 2);
        assert_eq!(broker.len(queues::TASKS).unwrap(), 6);
    }

    #[test]
    fn tree_setup_emits_combine_stages_in_order() {
        use crate::coordinator::agg::AggregationPlan;
        let broker = Broker::with_default_timeout();
        let store = Store::new();
        // 64 examples / 32 batch = 2 batches, minibatch 8 -> k = 4.
        let mut schedule = Schedule::tiny();
        schedule.batch_size = 32;
        schedule.examples_per_epoch = 64;
        let spec = ProblemSpec { schedule, learning_rate: 0.1 };
        let corpus = Corpus::synthetic_js(1, 2000);
        let plan = AggregationPlan::Tree { fanin: 2 };
        let summary =
            setup_problem_with(&broker, &store, &spec, &corpus, vec![0.0; 16], plan).unwrap();
        // k=4, fanin 2: one combine level with 2 nodes per batch.
        assert_eq!(summary.map_tasks, 8);
        assert_eq!(summary.combine_tasks, 4);
        assert_eq!(summary.reduce_tasks, 2);
        // Per-level queues exist for both batches.
        for batch in 0..2u32 {
            let b = BatchRef { epoch: 0, batch };
            assert_eq!(broker.len(&queues::agg_results(b, 0)).unwrap(), 0);
            assert_eq!(broker.len(&queues::agg_results(b, 1)).unwrap(), 0);
        }
        // Drain order: maps, combines (bottom-up), reduce — per batch.
        let mut kinds = Vec::new();
        while let Some(d) = broker
            .consume(queues::TASKS, Duration::from_millis(1))
            .unwrap()
        {
            let t = Task::decode(&d.payload).unwrap();
            kinds.push((t.kind_str(), t.model_version()));
            broker.ack(queues::TASKS, d.tag).unwrap();
        }
        assert_eq!(
            kinds,
            vec![
                ("map", 0),
                ("map", 0),
                ("map", 0),
                ("map", 0),
                ("combine", 0),
                ("combine", 0),
                ("reduce", 0),
                ("map", 1),
                ("map", 1),
                ("map", 1),
                ("map", 1),
                ("combine", 1),
                ("combine", 1),
                ("reduce", 1),
            ]
        );
    }

    #[test]
    fn queue_order_is_maps_then_reduce_per_batch() {
        let (broker, _store, _s) = tiny_setup();
        let mut kinds = Vec::new();
        while let Some(d) = broker
            .consume(queues::TASKS, Duration::from_millis(1))
            .unwrap()
        {
            let t = Task::decode(&d.payload).unwrap();
            kinds.push((t.kind_str(), t.model_version()));
            broker.ack(queues::TASKS, d.tag).unwrap();
        }
        assert_eq!(
            kinds,
            vec![
                ("map", 0),
                ("map", 0),
                ("reduce", 0),
                ("map", 1),
                ("map", 1),
                ("reduce", 1)
            ]
        );
    }

    #[test]
    fn async_setup_mirrors_flat_layout_with_staleness_fields() {
        use crate::coordinator::agg::AggregationPlan;
        let broker = Broker::with_default_timeout();
        let store = Store::new();
        let spec = ProblemSpec { schedule: Schedule::tiny(), learning_rate: 0.1 };
        let corpus = Corpus::synthetic_js(1, 2000);
        let plan = AggregationPlan::Async { tau: 3 };
        let summary =
            setup_problem_with(&broker, &store, &spec, &corpus, vec![0.0; 16], plan).unwrap();
        // Same counts and drain order as flat: no combine stages.
        assert_eq!(summary.map_tasks, 4);
        assert_eq!(summary.combine_tasks, 0);
        assert_eq!(summary.reduce_tasks, 2);
        let mut drained = Vec::new();
        while let Some(d) = broker.consume(queues::TASKS, Duration::from_millis(1)).unwrap() {
            let t = Task::decode(&d.payload).unwrap();
            drained.push(t.clone());
            broker.ack(queues::TASKS, d.tag).unwrap();
        }
        assert_eq!(drained.len(), 6);
        // Every task carries the bound: maps via the staleness field,
        // reduces via the embedded plan.
        for t in &drained {
            match t {
                Task::Map { staleness, .. } => assert_eq!(*staleness, Some(3)),
                Task::Reduce { plan: p, .. } => assert_eq!(*p, plan),
                Task::Combine { .. } => panic!("async plan emitted a combine"),
            }
        }
        assert_eq!(
            drained.iter().map(|t| (t.kind_str(), t.model_version())).collect::<Vec<_>>(),
            vec![("map", 0), ("map", 0), ("reduce", 0), ("map", 1), ("map", 1), ("reduce", 1)]
        );
    }

    #[test]
    fn volunteer_bootstrap_roundtrip() {
        let (_broker, store, _s) = tiny_setup();
        let (spec, corpus) = fetch_problem(&store).unwrap();
        assert_eq!(spec.schedule, Schedule::tiny());
        assert_eq!(corpus.len(), 2000);
        // Model v0 is live.
        let v = crate::coordinator::version::current_version(&store).unwrap();
        assert_eq!(v, Some(0));
    }

    #[test]
    fn job_scoped_setup_is_isolated_and_layout_identical() {
        use crate::coordinator::agg::AggregationPlan;
        use std::sync::Arc;
        let broker = Arc::new(Broker::with_default_timeout());
        let store = Arc::new(Store::new());
        let spec = ProblemSpec { schedule: Schedule::tiny(), learning_rate: 0.1 };
        let corpus = Corpus::synthetic_js(1, 2000);
        for job in ["alpha", "beta"] {
            let s = setup_problem_job(
                job,
                broker.clone(),
                store.clone(),
                &spec,
                &corpus,
                vec![0.0; 16],
                AggregationPlan::Flat,
            )
            .unwrap();
            assert_eq!(s.map_tasks, 4);
            assert_eq!(s.reduce_tasks, 2);
        }
        // Each job's InitialQueue filled independently; the bare names
        // were never created.
        assert_eq!(broker.len("alpha/tasks").unwrap(), 6);
        assert_eq!(broker.len("beta/tasks").unwrap(), 6);
        assert!(broker.len("tasks").is_err());
        // DataServer keys are prefixed per job, too.
        assert!(store.get("alpha/problem").unwrap().is_some());
        assert!(store.get("beta/corpus").unwrap().is_some());
        assert!(store.get("problem").unwrap().is_none());
    }

    #[test]
    fn setup_rejects_tiny_corpus() {
        let broker = Broker::with_default_timeout();
        let store = Store::new();
        let spec = ProblemSpec { schedule: Schedule::tiny(), learning_rate: 0.1 };
        let corpus = Corpus::from_encoded(vec![0u8; 300]).unwrap();
        // seq_len 40 fits in 300; shrink corpus below sample size via spec:
        let mut bad = spec;
        bad.schedule.seq_len = 299;
        assert!(setup_problem(&broker, &store, &bad, &corpus, vec![]).is_err());
    }
}
