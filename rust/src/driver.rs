//! End-to-end run drivers: wire Initiator + QueueServer + DataServer +
//! volunteer fleet together for one distributed training run (the leader
//! entrypoint used by the CLI, the examples, and the integration tests).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::config::Config;
use crate::coordinator::initiator::{setup_problem_with, SetupSummary};
use crate::coordinator::version::{get_model, wait_model};
use crate::coordinator::ProblemSpec;
use crate::data::{DataApi, Store};
use crate::faults::FaultPlan;
use crate::metrics::Timeline;
use crate::model::ModelSnapshot;
use crate::queue::broker::Broker;
use crate::queue::QueueApi;
use crate::runtime::Engine;
use crate::textdata::Corpus;
use crate::volunteer::agent::AgentOptions;
use crate::volunteer::pool::{run_pool, PoolOutcome};

/// Outcome of one distributed run.
#[derive(Debug)]
pub struct RunOutcome {
    pub setup: SetupSummary,
    pub pool: PoolOutcome,
    pub final_model: ModelSnapshot,
    /// Mean eval loss over every batch of the final epoch.
    pub final_loss: f32,
    pub timeline: Timeline,
}

/// Build the corpus a config describes.
pub fn load_corpus(cfg: &Config) -> Result<Corpus> {
    match &cfg.corpus_file {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading corpus {path:?}"))?;
            Corpus::from_text(&text)
        }
        None => Ok(Corpus::synthetic_js(cfg.corpus_seed, cfg.corpus_len)),
    }
}

/// Evaluate the model on every batch of the last epoch (B=128 artifact).
pub fn eval_final_loss(
    engine: &Engine,
    corpus: &Corpus,
    spec: &ProblemSpec,
    params: &[f32],
) -> Result<f32> {
    let s = &spec.schedule;
    let epoch = s.epochs - 1;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in 0..s.batches_per_epoch() {
        // The eval artifact is shape-specialized to B=128; fall back to
        // averaging map-batch losses when the schedule is smaller (tests).
        let (x, y) = s.batch(corpus, epoch, b);
        if y.len() == engine.meta().full_batch {
            total += engine.eval_loss(params, &x, &y)? as f64;
        } else {
            let k = s.minibatches_per_batch();
            let mut acc = 0.0f64;
            for m in 0..k {
                let (mx, my) = s.minibatch(corpus, epoch, b, m);
                let (_, loss) =
                    engine.grad_step(crate::runtime::GRAD_STEP_B8, params, &mx, &my)?;
                acc += loss as f64;
            }
            total += acc / k as f64;
        }
        count += 1;
    }
    Ok((total / count.max(1) as f64) as f32)
}

/// Run a full distributed training locally: in-process broker + store,
/// threaded volunteer fleet, real PJRT compute.
pub fn run_local(
    cfg: &Config,
    engine: &Arc<Engine>,
    plan: &FaultPlan,
    speeds: &[f64],
) -> Result<RunOutcome> {
    cfg.validate()?;
    let broker: Arc<Broker> = Arc::new(Broker::new(Duration::from_secs_f64(
        cfg.visibility_timeout_secs,
    )));
    let store: Arc<Store> = Arc::new(Store::new());
    run_with(cfg, engine, plan, speeds, broker, store)
}

/// Run with caller-provided broker/store (shared with a TCP server, or
/// pre-seeded by a test).
pub fn run_with(
    cfg: &Config,
    engine: &Arc<Engine>,
    plan: &FaultPlan,
    speeds: &[f64],
    broker: Arc<Broker>,
    store: Arc<Store>,
) -> Result<RunOutcome> {
    let corpus = load_corpus(cfg)?;
    let spec = ProblemSpec { schedule: cfg.schedule(), learning_rate: cfg.learning_rate };
    let init = engine.meta().load_init_params(&cfg.artifact_dir)?;
    let setup =
        setup_problem_with(broker.as_ref(), store.as_ref(), &spec, &corpus, init, cfg.agg_plan()?)?;

    let timeline = Timeline::new();
    let opts = AgentOptions {
        poll: Duration::from_secs_f64(cfg.task_poll_timeout_secs.min(0.5)),
        version_wait: Duration::from_secs_f64(cfg.visibility_timeout_secs / 4.0),
        speed: 1.0,
        t0: std::time::Instant::now(),
        // One task at a time preserves the paper's scheduling behaviour
        // for the determinism tests; classroom-mode processes opt into
        // prefetch explicitly (see AgentOptions::prefetch).
        prefetch: 1,
    };
    let broker_c = broker.clone();
    let store_c = store.clone();
    let conns = move |_i: usize| -> Result<(Arc<dyn QueueApi>, Arc<dyn DataApi>)> {
        Ok((broker_c.clone() as Arc<dyn QueueApi>, store_c.clone() as Arc<dyn DataApi>))
    };
    let pool = run_pool(engine, &conns, plan, speeds, Some(&timeline), &opts)?;

    // The fleet exits when the final version is live (or everyone left).
    let final_model = wait_model(store.as_ref(), spec.total_versions(), Duration::from_secs(5))?
        .or_else(|| get_model(store.as_ref()).ok().flatten())
        .ok_or_else(|| anyhow!("no model produced"))?;
    if final_model.version < spec.total_versions() {
        return Err(anyhow!(
            "training incomplete: version {}/{} (all volunteers left?)",
            final_model.version,
            spec.total_versions()
        ));
    }
    let final_loss = eval_final_loss(engine, &corpus, &spec, &final_model.params)?;
    Ok(RunOutcome { setup, pool, final_model, final_loss, timeline })
}
