//! Real PJRT backend (compiled only with `--features pjrt`).
//!
//! Requires the vendored `xla` bindings; see runtime/mod.rs for how the
//! stub/real split works. The API surface here is the contract the stub
//! mirrors — change both together.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::{EVAL_LOSS_B128, PREDICT_B1, RMSPROP_UPDATE};
use crate::model::ModelMeta;

/// A compiled model runtime: one PJRT client + one loaded executable per
/// artifact. Construction compiles everything up front (slow, once);
/// execution is the request-path hot loop.
pub struct Engine {
    client: xla::PjRtClient,
    meta: ModelMeta,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    artifact_dir: PathBuf,
}

// SAFETY: `PjRtClient`/`PjRtLoadedExecutable` wrap raw pointers to XLA's
// C++ PJRT objects, which are documented thread-safe (PJRT executables
// support concurrent Execute; the CPU client runs a thread pool). The Rust
// wrapper types are !Send/!Sync only because they contain raw pointers.
// We never mutate the maps after construction; all &self methods go
// straight to thread-safe C++ entry points.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load + compile every artifact listed in `model_meta.json`.
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let meta = ModelMeta::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        for (name, file) in &meta.artifacts {
            let path = artifact_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Engine { client, meta, exes, artifact_dir: artifact_dir.to_path_buf() })
    }

    /// Shared handle for multi-threaded volunteers.
    pub fn load_shared(artifact_dir: &Path) -> Result<Arc<Self>> {
        Ok(Arc::new(Self::load(artifact_dir)?))
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded (stale artifacts/?)"))
    }

    fn lit_f32(vals: &[f32]) -> xla::Literal {
        xla::Literal::vec1(vals)
    }

    fn lit_i32(vals: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(vals)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self.exe(name)?;
        let bufs = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))
    }

    /// Map task compute: minibatch gradient + loss.
    /// `artifact` selects the B=8 (map task) or B=128 (sequential baseline)
    /// entry point; x is row-major [B, seq_len], y is [B].
    pub fn grad_step(
        &self,
        artifact: &str,
        params: &[f32],
        x: &[i32],
        y: &[i32],
    ) -> Result<(Vec<f32>, f32)> {
        let b = y.len();
        if x.len() != b * self.meta.seq_len {
            bail!("x has {} elems, expected {}", x.len(), b * self.meta.seq_len);
        }
        if params.len() != self.meta.num_params {
            bail!("params len {} != {}", params.len(), self.meta.num_params);
        }
        let args = [
            Self::lit_f32(params),
            Self::lit_i32(x, &[b as i64, self.meta.seq_len as i64])?,
            Self::lit_i32(y, &[b as i64])?,
        ];
        let out = self.run(artifact, &args)?;
        let (grads_l, loss_l) = out
            .to_tuple2()
            .map_err(|e| anyhow!("grad_step output tuple: {e:?}"))?;
        let grads = grads_l.to_vec::<f32>().map_err(|e| anyhow!("grads: {e:?}"))?;
        let loss = loss_l
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?;
        Ok((grads, loss))
    }

    /// Reduce task compute: RMSprop update. Returns (params', ms').
    pub fn rmsprop_update(
        &self,
        params: &[f32],
        ms: &[f32],
        grads: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = self.meta.num_params;
        if params.len() != n || ms.len() != n || grads.len() != n {
            bail!("rmsprop arg length mismatch");
        }
        let args = [
            Self::lit_f32(params),
            Self::lit_f32(ms),
            Self::lit_f32(grads),
            Self::lit_f32(&[lr]),
        ];
        let out = self.run(RMSPROP_UPDATE, &args)?;
        let (p_l, ms_l) = out.to_tuple2().map_err(|e| anyhow!("rmsprop tuple: {e:?}"))?;
        Ok((
            p_l.to_vec::<f32>().map_err(|e| anyhow!("params': {e:?}"))?,
            ms_l.to_vec::<f32>().map_err(|e| anyhow!("ms': {e:?}"))?,
        ))
    }

    /// Evaluation loss over a full 128-batch.
    pub fn eval_loss(&self, params: &[f32], x: &[i32], y: &[i32]) -> Result<f32> {
        let args = [
            Self::lit_f32(params),
            Self::lit_i32(x, &[y.len() as i64, self.meta.seq_len as i64])?,
            Self::lit_i32(y, &[y.len() as i64])?,
        ];
        let out = self.run(EVAL_LOSS_B128, &args)?;
        let l = out.to_tuple1().map_err(|e| anyhow!("eval tuple: {e:?}"))?;
        l.get_first_element::<f32>().map_err(|e| anyhow!("loss: {e:?}"))
    }

    /// Next-char probabilities for one sample (text-generation demo).
    pub fn predict(&self, params: &[f32], x: &[i32]) -> Result<Vec<f32>> {
        if x.len() != self.meta.seq_len {
            bail!("predict expects one sample of seq_len");
        }
        let args = [
            Self::lit_f32(params),
            Self::lit_i32(x, &[1, self.meta.seq_len as i64])?,
        ];
        let out = self.run(PREDICT_B1, &args)?;
        let p = out.to_tuple1().map_err(|e| anyhow!("predict tuple: {e:?}"))?;
        p.to_vec::<f32>().map_err(|e| anyhow!("probs: {e:?}"))
    }
}
