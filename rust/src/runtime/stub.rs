//! Engine stand-in for builds without the PJRT backend.
//!
//! `load` always fails (no fake numerics can ever leak into a run), and
//! every compute method errors at runtime. The full signature surface of
//! the pjrt backend's `Engine` is mirrored so agents, drivers, benches,
//! and tests compile identically against either backend.
//!
//! [`Engine::protocol_only_for_tests`] constructs a compute-less engine
//! so queue/agent *protocol* paths (stale settlement, batched NACK
//! hand-back, prefetch grouping) can be integration-tested without AOT
//! artifacts — any accidental compute call fails the test loudly.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::model::ModelMeta;

/// Compute-less placeholder for the PJRT engine (see module docs).
pub struct Engine {
    _priv: (),
}

impl Engine {
    /// Always fails: the PJRT backend is not compiled in.
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        bail!(
            "PJRT backend not compiled in (artifacts at {artifact_dir:?}); \
             rebuild with --features pjrt and the vendored xla bindings"
        )
    }

    /// Shared handle for multi-threaded volunteers.
    pub fn load_shared(artifact_dir: &Path) -> Result<Arc<Self>> {
        Ok(Arc::new(Self::load(artifact_dir)?))
    }

    /// An engine whose every compute method errors: for tests that
    /// exercise the coordination protocol only (see module docs).
    pub fn protocol_only_for_tests() -> Self {
        Engine { _priv: () }
    }

    pub fn meta(&self) -> &ModelMeta {
        panic!("stub engine has no model metadata (build with --features pjrt)")
    }

    pub fn artifact_dir(&self) -> &Path {
        panic!("stub engine has no artifact dir (build with --features pjrt)")
    }

    pub fn platform(&self) -> String {
        "stub (no PJRT)".to_string()
    }

    /// Map task compute: minibatch gradient + loss.
    pub fn grad_step(
        &self,
        _artifact: &str,
        _params: &[f32],
        _x: &[i32],
        _y: &[i32],
    ) -> Result<(Vec<f32>, f32)> {
        bail!("stub engine cannot execute grad_step (build with --features pjrt)")
    }

    /// Reduce task compute: RMSprop update. Returns (params', ms').
    pub fn rmsprop_update(
        &self,
        _params: &[f32],
        _ms: &[f32],
        _grads: &[f32],
        _lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!("stub engine cannot execute rmsprop_update (build with --features pjrt)")
    }

    /// Evaluation loss over a full 128-batch.
    pub fn eval_loss(&self, _params: &[f32], _x: &[i32], _y: &[i32]) -> Result<f32> {
        bail!("stub engine cannot execute eval_loss (build with --features pjrt)")
    }

    /// Next-char probabilities for one sample (text-generation demo).
    pub fn predict(&self, _params: &[f32], _x: &[i32]) -> Result<Vec<f32>> {
        bail!("stub engine cannot execute predict (build with --features pjrt)")
    }
}
