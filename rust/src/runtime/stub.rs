//! Engine stand-in for builds without the PJRT backend.
//!
//! `load` always fails (no fake numerics can ever leak into a run), and
//! every compute method errors at runtime. The full signature surface of
//! the pjrt backend's `Engine` is mirrored so agents, drivers, benches,
//! and tests compile identically against either backend.
//!
//! Two explicit test-only constructors exist (a test has to opt in by
//! name; `load` still always fails):
//!
//! - [`Engine::protocol_only_for_tests`] — compute-less: queue/agent
//!   *protocol* paths (stale settlement, batched NACK hand-back, prefetch
//!   grouping) integration-test without AOT artifacts, and any accidental
//!   compute call fails the test loudly.
//! - [`Engine::exact_math_for_tests`] — a tiny deterministic "model"
//!   whose arithmetic is EXACT in f32: gradients are integer-valued
//!   (derived from the inputs plus the sign of each parameter, so model
//!   divergence propagates), and the update is `p - lr * g`. With a
//!   power-of-two minibatch count and a dyadic learning rate every fold
//!   is exactly associative, so aggregation topologies (flat vs
//!   tree:<fanin>, coordinator/agg.rs) must produce bit-identical final
//!   models — the invariant rust/tests/prop_invariants.rs checks across
//!   random volunteer interleavings without needing the PJRT toolchain.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::model::ModelMeta;

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    /// Every compute method errors (protocol-only tests).
    ProtocolOnly,
    /// Exact integer-valued test numerics (see module docs).
    ExactMath,
}

/// Compute-less placeholder for the PJRT engine (see module docs).
pub struct Engine {
    mode: Mode,
}

impl Engine {
    /// Always fails: the PJRT backend is not compiled in.
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        bail!(
            "PJRT backend not compiled in (artifacts at {artifact_dir:?}); \
             rebuild with --features pjrt and the vendored xla bindings"
        )
    }

    /// Shared handle for multi-threaded volunteers.
    pub fn load_shared(artifact_dir: &Path) -> Result<Arc<Self>> {
        Ok(Arc::new(Self::load(artifact_dir)?))
    }

    /// An engine whose every compute method errors: for tests that
    /// exercise the coordination protocol only (see module docs).
    pub fn protocol_only_for_tests() -> Self {
        Engine { mode: Mode::ProtocolOnly }
    }

    /// An engine with exact deterministic test numerics (see module
    /// docs): f32-associative gradients so fold-topology equivalence can
    /// be asserted bitwise. Never reachable from a real run — only tests
    /// construct it.
    pub fn exact_math_for_tests() -> Self {
        Engine { mode: Mode::ExactMath }
    }

    pub fn meta(&self) -> &ModelMeta {
        panic!("stub engine has no model metadata (build with --features pjrt)")
    }

    pub fn artifact_dir(&self) -> &Path {
        panic!("stub engine has no artifact dir (build with --features pjrt)")
    }

    pub fn platform(&self) -> String {
        match self.mode {
            Mode::ProtocolOnly => "stub (no PJRT)".to_string(),
            Mode::ExactMath => "stub (exact test math)".to_string(),
        }
    }

    /// Map task compute: minibatch gradient + loss.
    pub fn grad_step(
        &self,
        _artifact: &str,
        params: &[f32],
        x: &[i32],
        y: &[i32],
    ) -> Result<(Vec<f32>, f32)> {
        match self.mode {
            Mode::ProtocolOnly => {
                bail!("stub engine cannot execute grad_step (build with --features pjrt)")
            }
            Mode::ExactMath => {
                // Integer-valued gradient in [-3, 3]: a data term from the
                // sample plus sign(p) so parameter divergence feeds back.
                let base = (x.first().copied().unwrap_or(0) as i64
                    + y.first().copied().unwrap_or(0) as i64)
                    .rem_euclid(5)
                    - 2;
                let grads = params
                    .iter()
                    .enumerate()
                    .map(|(i, p)| {
                        let c = ((base + i as i64).rem_euclid(5) - 2) as f32;
                        // f32::signum maps 0.0 to 1.0; we want a true sign.
                        let s = if *p > 0.0 {
                            1.0
                        } else if *p < 0.0 {
                            -1.0
                        } else {
                            0.0
                        };
                        c + s
                    })
                    .collect();
                Ok((grads, 1.0))
            }
        }
    }

    /// Reduce task compute: RMSprop update. Returns (params', ms').
    pub fn rmsprop_update(
        &self,
        params: &[f32],
        ms: &[f32],
        grads: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        match self.mode {
            Mode::ProtocolOnly => {
                bail!("stub engine cannot execute rmsprop_update (build with --features pjrt)")
            }
            Mode::ExactMath => {
                if params.len() != grads.len() || ms.len() != params.len() {
                    bail!("length mismatch in exact-math rmsprop_update");
                }
                // Plain SGD stands in for RMSprop: with dyadic lr and
                // exact gradients the trajectory stays exactly
                // representable, which is all these tests need.
                let p2 = params.iter().zip(grads).map(|(p, g)| p - lr * g).collect();
                Ok((p2, ms.to_vec()))
            }
        }
    }

    /// Evaluation loss over a full 128-batch.
    pub fn eval_loss(&self, _params: &[f32], _x: &[i32], _y: &[i32]) -> Result<f32> {
        match self.mode {
            Mode::ProtocolOnly => {
                bail!("stub engine cannot execute eval_loss (build with --features pjrt)")
            }
            Mode::ExactMath => Ok(0.0),
        }
    }

    /// Next-char probabilities for one sample (text-generation demo).
    pub fn predict(&self, _params: &[f32], _x: &[i32]) -> Result<Vec<f32>> {
        bail!("stub engine cannot execute predict (build with --features pjrt)")
    }
}
