//! PJRT runtime (S11): load the AOT artifacts (HLO *text* — see
//! python/compile/aot.py for why text, not serialized protos) and execute
//! them on the CPU PJRT client. This is the only module that talks to the
//! PJRT bindings; everything above deals in plain `&[f32]` / `&[i32]`.
//!
//! Python never runs here: after `make artifacts` the Rust binary is
//! self-contained.
//!
//! The PJRT backend needs the XLA toolchain, which not every build host
//! has (CI runs the coordination stack alone). The crate therefore ships
//! two interchangeable [`Engine`] definitions:
//!
//! - `--features pjrt` — `pjrt::Engine`, the real thing (requires the
//!   vendored `xla` bindings to be wired into Cargo.toml);
//! - default — `stub::Engine`, whose `load` always fails with a clear
//!   message and whose compute methods error at runtime. Everything that
//!   merely *holds* an engine (agents, drivers, benches) compiles and
//!   runs; tests that need real compute skip themselves when
//!   `Engine::load` fails (see rust/tests/common/mod.rs), while protocol
//!   paths test against `Engine::protocol_only_for_tests`.

use std::path::PathBuf;

/// Names of the AOT entry points (must match aot.py's artifact set).
pub const GRAD_STEP_B8: &str = "grad_step_b8";
pub const GRAD_STEP_B128: &str = "grad_step_b128";
pub const RMSPROP_UPDATE: &str = "rmsprop_update";
pub const EVAL_LOSS_B128: &str = "eval_loss_b128";
pub const PREDICT_B1: &str = "predict_b1";

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Engine;

/// Resolve the artifact directory: $JSDOOP_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("JSDOOP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
