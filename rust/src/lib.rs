//! # jsdoop — volunteer distributed NN training, reproduced in Rust+JAX+Pallas
//!
//! Reproduction of *"JSDoop and TensorFlow.js: Volunteer Distributed Web
//! Browser-Based Neural Network Training"* (Morell, Camero, Alba — IEEE
//! Access 2019, 10.1109/ACCESS.2019.2950287) as a three-layer stack:
//!
//! - **L3 (this crate)** — the JSDoop coordination system: queue broker
//!   ([`queue`]), data server ([`data`]), initiator + execution flow
//!   ([`coordinator`]), volunteer agents ([`volunteer`]), discrete-event
//!   simulator ([`simclock`]), fault injection ([`faults`]), bench
//!   metrics ([`metrics`]), live observability ([`obs`]).
//! - **L2/L1 (build-time Python)** — the char-RNN model (JAX) over fused
//!   Pallas LSTM kernels, AOT-lowered to the HLO artifacts executed by
//!   [`runtime`].
//!
//! See `README.md` for the build/test/bench quickstart and the three-layer
//! architecture sketch; `rust/benches/` maps every figure/table of the
//! paper to a bench target.

pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod driver;
pub mod faults;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod profiles;
pub mod queue;
pub mod runtime;
pub mod simclock;
pub mod testutil;
pub mod textdata;
pub mod util;
pub mod volunteer;
