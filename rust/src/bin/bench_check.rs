//! bench_check — gate CI on bench regressions.
//!
//! Compares fresh `BENCH_*.json` outputs (written by the bench targets via
//! `metrics::write_bench_json`) against the committed floors in
//! `bench_baselines/`, and fails when a gated metric regressed more than
//! `BENCH_CHECK_TOLERANCE_PCT` percent (default 25).
//!
//! Row semantics follow the emitters:
//!   - a row whose baseline carries a `speedup` is a ratio vs. an in-run
//!     baseline (robust to runner speed) — fresh speedup must stay at or
//!     above `baseline * (1 - tol)`;
//!   - a row without one is compared on `ns_per_op` as a lower-is-better
//!     value (only deterministic counts / byte figures are committed as
//!     baselines; raw wall-clock rows are deliberately left out).
//!
//! Only ops present in a baseline file are gated; everything else in the
//! fresh JSONs is informational. A baseline op missing from the fresh run
//! warns but does not fail (degraded runners skip tiers).
//!
//! Usage: bench_check [FRESH_DIR] [--baselines DIR]
//!   FRESH_DIR (default ".") is searched recursively — pointing it at a
//!   directory of downloaded CI artifacts works as-is.
//!
//! Refreshing baselines after an intentional perf change:
//!   cargo bench && cp rust/BENCH_*.json rust/bench_baselines/   (from the
//!   repo root; commit the diff with a note on what moved and why).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use jsdoop::util::json::Json;

struct Row {
    ns_per_op: f64,
    speedup: Option<f64>,
}

fn parse_rows(text: &str) -> Result<BTreeMap<String, Row>, String> {
    let json = Json::parse(text)?;
    let arr = json.as_arr().ok_or("top level is not an array")?;
    let mut out = BTreeMap::new();
    for item in arr {
        let op = item.req("op")?.as_str().ok_or("'op' is not a string")?.to_string();
        let ns_per_op = item.req("ns_per_op")?.as_f64().ok_or("'ns_per_op' is not a number")?;
        let speedup = item.get("speedup").and_then(|v| v.as_f64());
        out.insert(op, Row { ns_per_op, speedup });
    }
    Ok(out)
}

/// One gated row: `Ok(diagnostic)` when within tolerance, `Err(reason)`
/// on regression.
fn check_row(base: &Row, fresh: &Row, tol_pct: f64) -> Result<String, String> {
    if let Some(bs) = base.speedup {
        let floor = bs * (1.0 - tol_pct / 100.0);
        match fresh.speedup {
            Some(fs) if fs >= floor => {
                Ok(format!("speedup {fs:.2} >= floor {floor:.2} (baseline {bs:.2})"))
            }
            Some(fs) => {
                Err(format!("speedup regressed: {fs:.2} < floor {floor:.2} (baseline {bs:.2})"))
            }
            None => Err(format!("baseline gates a speedup ({bs:.2}) but the fresh row has none")),
        }
    } else {
        let cap = base.ns_per_op * (1.0 + tol_pct / 100.0);
        if fresh.ns_per_op <= cap {
            Ok(format!(
                "value {:.1} <= cap {:.1} (baseline {:.1})",
                fresh.ns_per_op, cap, base.ns_per_op
            ))
        } else {
            Err(format!(
                "value regressed: {:.1} > cap {:.1} (baseline {:.1})",
                fresh.ns_per_op, cap, base.ns_per_op
            ))
        }
    }
}

fn find_bench_jsons(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            find_bench_jsons(&p, out);
        } else if let Some(name) = p.file_name().and_then(|n| n.to_str()) {
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                out.push(p);
            }
        }
    }
}

fn load(path: &Path) -> Result<BTreeMap<String, Row>, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    parse_rows(&text).map_err(|e| format!("{}: {e}", path.display()))
}

fn main() -> ExitCode {
    let mut fresh_dir = PathBuf::from(".");
    let mut baselines_dir = PathBuf::from("bench_baselines");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--baselines" {
            match args.next() {
                Some(d) => baselines_dir = PathBuf::from(d),
                None => {
                    eprintln!("--baselines needs a directory argument");
                    return ExitCode::FAILURE;
                }
            }
        } else {
            fresh_dir = PathBuf::from(a);
        }
    }
    let tol_pct = std::env::var("BENCH_CHECK_TOLERANCE_PCT")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(25.0);

    let mut baseline_files = Vec::new();
    find_bench_jsons(&baselines_dir, &mut baseline_files);
    baseline_files.sort();
    if baseline_files.is_empty() {
        eprintln!(
            "no BENCH_*.json baselines under {} — run from rust/ (or pass --baselines)",
            baselines_dir.display()
        );
        return ExitCode::FAILURE;
    }
    let mut fresh_files = Vec::new();
    find_bench_jsons(&fresh_dir, &mut fresh_files);
    fresh_files.sort();

    let mut failures: Vec<String> = Vec::new();
    let mut gated = 0usize;
    for base_path in &baseline_files {
        let file_name = base_path.file_name().and_then(|n| n.to_str()).unwrap_or("?");
        let base_rows = match load(base_path) {
            Ok(r) => r,
            Err(e) => {
                failures.push(format!("unreadable baseline {e}"));
                continue;
            }
        };
        let fresh_path = fresh_files
            .iter()
            .find(|p| p.file_name().and_then(|n| n.to_str()) == Some(file_name));
        let Some(fresh_path) = fresh_path else {
            println!(
                "WARN  {file_name}: no fresh copy under {} — skipped (bench not run?)",
                fresh_dir.display()
            );
            continue;
        };
        let fresh_rows = match load(fresh_path) {
            Ok(r) => r,
            Err(e) => {
                failures.push(format!("unreadable fresh {e}"));
                continue;
            }
        };
        for (op, base) in &base_rows {
            match fresh_rows.get(op) {
                Some(fresh) => {
                    gated += 1;
                    match check_row(base, fresh, tol_pct) {
                        Ok(msg) => println!("ok    {file_name} / {op}: {msg}"),
                        Err(msg) => {
                            println!("FAIL  {file_name} / {op}: {msg}");
                            failures.push(format!("{file_name} / {op}: {msg}"));
                        }
                    }
                }
                None => println!("WARN  {file_name} / {op}: missing from the fresh run — skipped"),
            }
        }
    }

    if failures.is_empty() {
        println!("bench_check: {gated} gated rows within {tol_pct}% tolerance");
        ExitCode::SUCCESS
    } else {
        println!("bench_check: {} regression(s) past {tol_pct}% tolerance:", failures.len());
        for f in &failures {
            println!("  - {f}");
        }
        println!(
            "If the change is intentional, refresh the floors:\n  \
             cargo bench && cp rust/BENCH_*.json rust/bench_baselines/\n\
             then commit the updated baselines with a note on what moved and why."
        );
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use jsdoop::metrics::{bench_json_string, BenchRow};

    fn row(ns: f64, speedup: Option<f64>) -> Row {
        Row { ns_per_op: ns, speedup }
    }

    #[test]
    fn parses_rows_emitted_by_the_bench_serializer() {
        let text = bench_json_string(&[
            BenchRow { op: "a".into(), iters: 3, ns_per_op: 10.0, speedup: Some(2.5) },
            BenchRow { op: "b".into(), iters: 1, ns_per_op: 7.0, speedup: None },
        ]);
        let rows = parse_rows(&text).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows["a"].speedup, Some(2.5));
        assert_eq!(rows["b"].ns_per_op, 7.0);
        assert_eq!(rows["b"].speedup, None);
    }

    #[test]
    fn speedup_rows_gate_on_the_ratio_not_the_timing() {
        // Timing got worse but the in-run ratio held: fine.
        let base = row(10.0, Some(2.0));
        assert!(check_row(&base, &row(500.0, Some(1.9)), 25.0).is_ok());
        // Ratio collapsed past the tolerance: regression.
        assert!(check_row(&base, &row(5.0, Some(1.4)), 25.0).is_err());
        // Exactly at the floor passes.
        assert!(check_row(&base, &row(5.0, Some(1.5)), 25.0).is_ok());
        // A fresh row that lost its speedup field entirely fails loudly.
        assert!(check_row(&base, &row(5.0, None), 25.0).is_err());
    }

    #[test]
    fn value_rows_gate_lower_is_better() {
        let base = row(100.0, None);
        assert!(check_row(&base, &row(124.0, None), 25.0).is_ok());
        assert!(check_row(&base, &row(126.0, None), 25.0).is_err());
        assert!(check_row(&base, &row(1.0, None), 25.0).is_ok());
    }
}
