//! Discrete-event simulation engine (S9).
//!
//! The paper's experiments run for minutes to hours of wall-clock time
//! (Table 4: 177 minutes for one cluster worker). To regenerate every
//! figure deterministically and in milliseconds, the volunteer simulator
//! (`volunteer::sim`) runs the *same protocol state machine* on a virtual
//! clock: a priority queue of (time, seq, event), with seq breaking ties
//! FIFO so equal-time events replay identically.
//!
//! Time is f64 seconds since experiment start (matching the paper's axes).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual time, carrying an opaque payload `E`.
struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first, then
        // smallest-seq-first for deterministic FIFO tie-breaking.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The virtual clock + event queue.
pub struct SimClock<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<E> Default for SimClock<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> SimClock<E> {
    pub fn new() -> Self {
        SimClock { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events executed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at `now + delay` (delay clamped to >= 0).
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        let t = self.now + delay.max(0.0);
        self.schedule_at(t, event);
    }

    /// Schedule `event` at absolute time `t` (clamped to >= now).
    pub fn schedule_at(&mut self, t: f64, event: E) {
        let time = if t < self.now { self.now } else { t };
        assert!(time.is_finite(), "non-finite event time");
        self.heap.push(Scheduled { time, seq: self.seq, event });
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock to its time.
    pub fn next(&mut self) -> Option<(f64, E)> {
        let s = self.heap.pop()?;
        debug_assert!(s.time >= self.now, "time went backwards");
        self.now = s.time;
        self.processed += 1;
        Some((s.time, s.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut c = SimClock::new();
        c.schedule_in(5.0, "c");
        c.schedule_in(1.0, "a");
        c.schedule_in(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| c.next().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(c.now(), 5.0);
        assert_eq!(c.processed(), 3);
    }

    #[test]
    fn ties_break_fifo() {
        let mut c = SimClock::new();
        for i in 0..10 {
            c.schedule_at(2.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| c.next().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut c = SimClock::new();
        c.schedule_in(2.0, ());
        c.next();
        // Scheduling in the past clamps to now.
        c.schedule_at(1.0, ());
        let (t, _) = c.next().unwrap();
        assert_eq!(t, 2.0);
    }

    #[test]
    fn negative_delay_clamps() {
        let mut c = SimClock::new();
        c.schedule_in(-5.0, "x");
        let (t, _) = c.next().unwrap();
        assert_eq!(t, 0.0);
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut c = SimClock::new();
        c.schedule_in(1.0, 1);
        let (_, e) = c.next().unwrap();
        assert_eq!(e, 1);
        c.schedule_in(1.0, 2); // at t=2
        c.schedule_in(0.5, 3); // at t=1.5
        assert_eq!(c.next().unwrap(), (1.5, 3));
        assert_eq!(c.next().unwrap(), (2.0, 2));
        assert!(c.is_empty());
    }
}
