//! Calibrated simulation profiles for the paper's two testbeds (§V.A-B).
//!
//! The absolute constants are calibrations, not measurements of the
//! authors' hardware; what the benches assert is the SHAPE of the results
//! (who wins, superlinearity region, the 16-task synchronization wall) —
//! see DESIGN.md "Experiment index". Calibration notes in EXPERIMENTS.md.
//!
//! **cluster** — "more than 32 heterogeneous computers of different
//! performances administrated with HTCondor". Heterogeneous speeds, the
//! scheduler hands out the SLOWEST nodes first (idle-first fill), plus the
//! Foster cache effect: both are required to reproduce the paper's heavily
//! superlinear relative speedups (4.8x at 2 workers) — a slow 1-worker
//! baseline plus thrashing.
//!
//! **classroom** — 32 student machines running browsers: faster, LAN-local,
//! but with high service-time variance (foreground browsing); straggler
//! re-issue (short visibility window + fast priority-swap probing) trims
//! the jitter tail. NOTE: the paper's own Table 4 shows classroom-32 at
//! 2.16x classroom-16, which contradicts its own §V.A analysis ("no
//! scalability with more than 16 devices is possible" — the 16-map + 1
//! reduce lock-step). Under the protocol as described, W > 17 only adds
//! redundancy; we reproduce the theory-consistent plateau and discuss the
//! discrepancy in EXPERIMENTS.md E4.

use crate::faults::FaultPlan;
use crate::util::prng::Rng;
use crate::volunteer::sim::SimParams;

/// HTCondor-like speed pool: slowest-first. Node 0 is the dusty Pentium in
/// the rack bottom (speed 0.22); later nodes approach and exceed 1.0.
pub fn cluster_speed_pool(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut speeds = Vec::with_capacity(n);
    for i in 0..n {
        // Deterministic sqrt ramp + mild jitter: 0.20 .. ~1.45. The sqrt
        // makes the first nodes markedly slower than the pack, which is
        // what drives the paper's strongly superlinear S(2)..S(4).
        let ramp = 0.20 + 1.25 * (i as f64 / 31.0).min(1.0).sqrt();
        let j = 1.0 + 0.08 * (rng.f64() - 0.5);
        speeds.push(ramp * j);
    }
    speeds
}

/// Cluster profile (Fig 4-6, Table 4 "JSDoop-cluster").
pub fn cluster(workers: usize, rng: &mut Rng) -> (SimParams, Vec<f64>, FaultPlan) {
    let params = SimParams {
        t_map: 4.2,
        t_reduce: 4.0,
        // Combine folds are pure vector adds over <= fanin inputs — far
        // cheaper than the reduce's fold + RMSprop + model exchange.
        // Only used when a run opts into --agg=tree:<fanin>; the default
        // flat plan leaves the calibrated figures bit-identical.
        t_combine: 1.0,
        rtt: 0.05,
        model_fetch: 0.35,
        model_push: 0.35,
        grad_push: 0.25,
        grad_collect: 0.15,
        cache_capacity: 96,
        cache_miss_penalty: 0.7,
        jitter_sigma: 0.08,
        visibility_timeout: 300.0,
        requeue_on_disconnect: true,
        poll: 0.5,
        version_wait: 30.0,
        ..SimParams::default()
    };
    let speeds = cluster_speed_pool(workers, rng);
    (params, speeds, FaultPlan::sync_start(workers))
}

/// Classroom machine speeds: uniformly fast (modern laptops), small spread.
pub fn classroom_speeds(n: usize) -> Vec<f64> {
    (0..n).map(|i| 3.1 + 0.2 * ((i % 5) as f64 / 4.0)).collect()
}

fn classroom_params() -> SimParams {
    SimParams {
        t_map: 4.2,
        t_reduce: 2.4,
        t_combine: 0.6,
        rtt: 0.01,
        model_fetch: 0.10,
        model_push: 0.10,
        grad_push: 0.06,
        grad_collect: 0.03,
        cache_capacity: 96,
        cache_miss_penalty: 0.25,
        // Students keep browsing: heavy-tailed service times.
        jitter_sigma: 0.85,
        // Tight visibility window: stragglers get re-issued quickly and
        // the spare half of a 32-volunteer fleet rescues them.
        visibility_timeout: 3.0,
        requeue_on_disconnect: true,
        poll: 0.25,
        // Browsers probe fast: swap-rescue of redelivered stragglers
        // within ~1s.
        version_wait: 1.0,
        ..SimParams::default()
    }
}

/// Classroom, everyone already on the page (Table 4 "sync-start").
pub fn classroom(workers: usize) -> (SimParams, Vec<f64>, FaultPlan) {
    (classroom_params(), classroom_speeds(workers), FaultPlan::sync_start(workers))
}

/// Classroom, volunteers trickling in over ~40s (Table 4 "async-start").
pub fn classroom_async(workers: usize, rng: &mut Rng) -> (SimParams, Vec<f64>, FaultPlan) {
    (
        classroom_params(),
        classroom_speeds(workers),
        FaultPlan::async_start(workers, 40.0, rng),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::volunteer::sim::{simulate, SimWorkload};

    fn run(profile: &str, workers: usize) -> f64 {
        let mut rng = Rng::new(42);
        let (p, s, plan) = match profile {
            "cluster" => cluster(workers, &mut rng),
            "classroom" => classroom(workers),
            "classroom-async" => classroom_async(workers, &mut rng),
            _ => unreachable!(),
        };
        simulate(SimWorkload::paper(), &p, &plan, &s, 42).unwrap().runtime
    }

    #[test]
    fn cluster_speed_pool_is_slowest_first() {
        let mut rng = Rng::new(1);
        let s = cluster_speed_pool(32, &mut rng);
        assert!(s[0] < 0.3);
        assert!(s[31] > 1.1);
    }

    #[test]
    fn cluster_superlinear_then_wall() {
        let t1 = run("cluster", 1);
        let t2 = run("cluster", 2);
        let t16 = run("cluster", 16);
        let t32 = run("cluster", 32);
        // Superlinear relative speedup at 2 and 16; sublinear at 32.
        assert!(t1 / t2 > 2.0, "S(2) = {}", t1 / t2);
        assert!(t1 / t16 > 16.0, "S(16) = {}", t1 / t16);
        assert!(t1 / t32 < 32.0, "S(32) = {}", t1 / t32);
        // The 16-minibatch wall: 32 barely beats 16.
        assert!(t32 < t16, "t32 {} vs t16 {}", t32, t16);
        assert!(t32 > t16 * 0.6, "32 workers cannot break the sync wall");
    }

    #[test]
    fn classroom_beats_cluster_and_plateaus_past_16() {
        let cl16 = run("classroom", 16);
        let cl32 = run("classroom", 32);
        let cu16 = run("cluster", 16);
        let cu32 = run("cluster", 32);
        // Classroom machines are faster: both sizes beat the cluster.
        assert!(cl32 < cu32, "classroom-32 {} should beat cluster-32 {}", cl32, cu32);
        assert!(cl16 < cu16, "classroom-16 {} should beat cluster-16 {}", cl16, cu16);
        // The 16-map lock-step wall: 32 volunteers no worse, not much
        // better (see module docs on the paper's Table 4 anomaly).
        assert!(cl32 < cl16 * 1.05, "cl32 {} vs cl16 {}", cl32, cl16);
    }

    #[test]
    fn tree_aggregation_unclogs_the_calibrated_reducer() {
        // On the calibrated cluster profile at 32 workers, tree:4 must
        // cut the busiest agent's per-step gradient traffic vs the
        // paper-faithful flat plan (the Fig-6 bottleneck this topology
        // exists for) while completing the identical workload.
        use crate::volunteer::sim::AggregationPlan;
        let mut rng = Rng::new(42);
        let (p_flat, s, plan) = cluster(32, &mut rng);
        let flat = simulate(SimWorkload::paper(), &p_flat, &plan, &s, 42).unwrap();
        let p_tree =
            SimParams { agg: AggregationPlan::Tree { fanin: 4 }, ..p_flat.clone() };
        let tree = simulate(SimWorkload::paper(), &p_tree, &plan, &s, 42).unwrap();
        assert_eq!(tree.reduces_done, flat.reduces_done);
        assert!(
            tree.critical_grad_vecs_per_step < flat.critical_grad_vecs_per_step,
            "tree {} vs flat {}",
            tree.critical_grad_vecs_per_step,
            flat.critical_grad_vecs_per_step
        );
    }

    #[test]
    fn async_start_slower_than_sync() {
        // At 32 volunteers the 17-task lock-step hides a 40s ramp-in
        // almost entirely (paper: 2.7 vs 2.5 min) — only require "not
        // better, not blown up".
        let sync32 = run("classroom", 32);
        let async32 = run("classroom-async", 32);
        assert!(async32 > sync32 * 0.95, "async32 {async32} vs sync32 {sync32}");
        assert!(async32 < sync32 * 2.0, "async should not blow up");
        // At 16 volunteers every machine matters: ramp-in must cost time.
        let sync16 = run("classroom", 16);
        let async16 = run("classroom-async", 16);
        assert!(async16 > sync16, "async16 {async16} vs sync16 {sync16}");
    }
}
