//! Two tenants, one volunteer fleet.
//!
//! Declares two independent training jobs on a single durable broker —
//! a char-RNN-shaped job ("lstm") and a smaller MLP-shaped job ("mlp"),
//! both on the deterministic exact-math stub so this runs without any
//! PJRT artifacts — then drives three volunteers that pull work from
//! BOTH jobs through the fair-share consume path. Each job finishes
//! bit-identical to the model it would have produced on a private
//! deployment: the co-tenant can shift timing, never numerics.
//!
//! Each tenant's aggregation topology comes from the `--job_agg`
//! config key (config::Config::agg_plan_for_job), defaulting to
//! `lstm=flat,mlp=tree:2`; pass e.g. `--job_agg=lstm=async:2,mlp=flat`
//! to re-plan either job (bit-identity vs the solo oracle is only
//! asserted for sync plans and `async:0`).
//!
//!     cargo run --release --example two_jobs

#[cfg(feature = "pjrt")]
fn main() {
    eprintln!("two_jobs uses the exact-math stub; build without --features pjrt");
}

#[cfg(not(feature = "pjrt"))]
fn main() -> anyhow::Result<()> {
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::time::Duration;

    use jsdoop::coordinator::agg::AggregationPlan;
    use jsdoop::coordinator::initiator::setup_problem_job;
    use jsdoop::coordinator::version::get_model;
    use jsdoop::coordinator::ProblemSpec;
    use jsdoop::data::{DataApi, Store};
    use jsdoop::queue::durability::{DurabilityOptions, DurableBroker, SyncPolicy};
    use jsdoop::queue::job::{JobData, JobQuota, JobQueueApi};
    use jsdoop::runtime::Engine;
    use jsdoop::textdata::{Corpus, Schedule};
    use jsdoop::volunteer::agent::{AgentOptions, MultiJobAgent};

    // Two workload families with different model sizes, schedules,
    // learning rates, and aggregation topologies.
    let lstm_spec = ProblemSpec {
        schedule: Schedule {
            seq_len: 10,
            batch_size: 8,
            minibatch_size: 2,
            examples_per_epoch: 32,
            epochs: 1,
        },
        learning_rate: 0.25,
    };
    let mlp_spec = ProblemSpec {
        schedule: Schedule {
            seq_len: 8,
            batch_size: 6,
            minibatch_size: 2,
            examples_per_epoch: 18,
            epochs: 1,
        },
        learning_rate: 0.5,
    };
    let lstm_corpus = Corpus::synthetic_js(7, 4000);
    let mlp_corpus = Corpus::synthetic_js(13, 3000);

    let engine = Engine::exact_math_for_tests();
    println!("engine: {}", engine.platform());

    // Per-job topology via the real config key (CLI-overridable).
    let mut cfg = jsdoop::config::Config::default();
    cfg.job_agg = "lstm=flat,mlp=tree:2".to_string();
    cfg.apply_cli(&std::env::args().skip(1).collect::<Vec<_>>())?;
    cfg.validate()?;
    let lstm_plan = cfg.agg_plan_for_job("lstm")?;
    let mlp_plan = cfg.agg_plan_for_job("mlp")?;
    println!("plans: lstm={lstm_plan} mlp={mlp_plan} (--job_agg={})", cfg.job_agg);

    // Solo oracles: what each job must produce regardless of tenancy.
    let lstm_oracle = jsdoop::baseline::train_accumulated_with_plan(
        &engine,
        &lstm_corpus,
        &lstm_spec,
        vec![0.0f32; 5],
        lstm_plan,
    )?
    .snapshot
    .params;
    let mlp_oracle = jsdoop::baseline::train_accumulated_with_plan(
        &engine,
        &mlp_corpus,
        &mlp_spec,
        vec![0.0f32; 7],
        mlp_plan,
    )?
    .snapshot
    .params;

    // One durable broker + one data store serve both tenants.
    let dir = std::env::temp_dir().join(format!("jsdoop-two-jobs-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = DurabilityOptions {
        sync: SyncPolicy::EveryN(5),
        compact_after_bytes: u64::MAX,
        visibility_timeout: Duration::from_secs(5),
        ..Default::default()
    };
    let broker = Arc::new(DurableBroker::open(&dir, opts)?);
    let store = Arc::new(Store::new());

    // The bigger job gets a ready-backlog cap; the small one is unmetered.
    broker.set_job_quota(
        "lstm",
        JobQuota { max_ready_msgs: 10_000, max_ready_bytes: 64 << 20 },
    )?;
    setup_problem_job(
        "lstm",
        broker.clone() as Arc<dyn JobQueueApi>,
        store.clone() as Arc<dyn DataApi>,
        &lstm_spec,
        &lstm_corpus,
        vec![0.0f32; 5],
        lstm_plan,
    )?;
    setup_problem_job(
        "mlp",
        broker.clone() as Arc<dyn JobQueueApi>,
        store.clone() as Arc<dyn DataApi>,
        &mlp_spec,
        &mlp_corpus,
        vec![0.0f32; 7],
        mlp_plan,
    )?;
    for j in broker.list_jobs()? {
        println!(
            "job {:<5} queues={} ready={} msgs / {} B  quota={:?}",
            j.job, j.queues, j.ready_msgs, j.ready_bytes, j.quota
        );
    }

    // Three volunteers, each serving BOTH jobs via fair-share pulls.
    let jobids = vec!["lstm".to_string(), "mlp".to_string()];
    let quit = AtomicBool::new(false);
    let agent_opts = AgentOptions {
        poll: Duration::from_millis(20),
        version_wait: Duration::from_millis(150),
        prefetch: 2,
        ..Default::default()
    };
    let reports: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|id| {
                let broker = broker.clone();
                let store = store.clone();
                let engine = &engine;
                let quit = &quit;
                let jobids = jobids.clone();
                let agent_opts = agent_opts.clone();
                s.spawn(move || {
                    let agent = MultiJobAgent {
                        id,
                        engine,
                        queue: broker as Arc<dyn JobQueueApi>,
                        data: store as Arc<dyn DataApi>,
                        timeline: None,
                        opts: agent_opts,
                    };
                    agent.run(&jobids, quit)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (id, r) in reports.iter().enumerate() {
        let r = r.as_ref().map_err(|e| anyhow::anyhow!("volunteer {id}: {e}"))?;
        for (job, rep) in r {
            println!(
                "  volunteer {id} on {job:<5}: {} maps, {} reduces",
                rep.maps_done, rep.reduces_done
            );
        }
    }

    // Both tenants must match their private-deployment oracles exactly —
    // except under async with tau > 0, where divergence from the
    // synchronous oracle is bounded, not zero (tests/agg_topology.rs).
    let bit_exact =
        |p: &AggregationPlan| !matches!(p, AggregationPlan::Async { tau } if *tau > 0);
    let lstm_view = JobData::new("lstm", store.clone() as Arc<dyn DataApi>)?;
    let mlp_view = JobData::new("mlp", store.clone() as Arc<dyn DataApi>)?;
    let lstm_model = get_model(&lstm_view)?.expect("lstm: no model");
    let mlp_model = get_model(&mlp_view)?.expect("mlp: no model");
    anyhow::ensure!(
        !bit_exact(&lstm_plan) || lstm_model.params == lstm_oracle,
        "lstm diverged from its solo oracle"
    );
    anyhow::ensure!(
        !bit_exact(&mlp_plan) || mlp_model.params == mlp_oracle,
        "mlp diverged from its solo oracle"
    );
    println!(
        "both jobs converged bit-identical to their solo oracles \
         (lstm v{}, mlp v{})",
        lstm_model.version, mlp_model.version
    );

    drop(broker);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
