//! Classroom scenario (paper §V.B) over REAL TCP: a QueueServer+DataServer
//! process boundary, volunteers dialing in over the wire (the browser /
//! WebSocket analog), and the paper's three scenarios:
//!   1. async-start: volunteers trickle in
//!   2. sync-start: all 8 already connected
//!   3. churn: half the volunteers close their tab mid-run
//! Each run uses real PJRT compute on a scaled schedule and prints the
//! per-scenario wall-clock + a Fig-7-style timeline.
//!
//!     make artifacts && cargo run --release --example classroom

use std::sync::Arc;
use std::time::Duration;

use jsdoop::config::Config;
use jsdoop::coordinator::initiator::setup_problem;
use jsdoop::coordinator::ProblemSpec;
use jsdoop::data::{DataApi, Store};
use jsdoop::driver;
use jsdoop::faults::FaultPlan;
use jsdoop::metrics::Timeline;
use jsdoop::queue::broker::Broker;
use jsdoop::queue::client::{RemoteData, RemoteQueue};
use jsdoop::queue::server::serve;
use jsdoop::queue::QueueApi;
use jsdoop::runtime::Engine;
use jsdoop::util::prng::Rng;
use jsdoop::volunteer::agent::AgentOptions;
use jsdoop::volunteer::pool::run_pool;

const WORKERS: usize = 8;

fn scenario(
    name: &str,
    engine: &Arc<Engine>,
    cfg: &Config,
    plan: &FaultPlan,
) -> anyhow::Result<f64> {
    // Fresh servers per scenario (fresh problem state).
    let broker = Arc::new(Broker::new(Duration::from_secs_f64(cfg.visibility_timeout_secs)));
    let store = Arc::new(Store::new());
    let handle = serve("127.0.0.1:0", broker, store)?;
    let addr = handle.addr.to_string();

    // Initiator publishes over the wire.
    {
        let q = RemoteQueue::connect(&addr)?;
        let d = RemoteData::connect(&addr)?;
        let spec = ProblemSpec { schedule: cfg.schedule(), learning_rate: cfg.learning_rate };
        let corpus = driver::load_corpus(cfg)?;
        let init = engine.meta().load_init_params(&cfg.artifact_dir)?;
        setup_problem(&q, &d, &spec, &corpus, init)?;
    }

    // Volunteers dial in over TCP (one connection pair each).
    let timeline = Timeline::new();
    let opts = AgentOptions {
        poll: Duration::from_millis(100),
        version_wait: Duration::from_secs(2),
        ..Default::default()
    };
    let addr2 = addr.clone();
    let conns = move |_i: usize| -> anyhow::Result<(
        Arc<dyn QueueApi>,
        Arc<dyn DataApi>,
    )> {
        Ok((
            Arc::new(RemoteQueue::connect(&addr2)?) as Arc<dyn QueueApi>,
            Arc::new(RemoteData::connect(&addr2)?) as Arc<dyn DataApi>,
        ))
    };
    let outcome = run_pool(engine, &conns, plan, &vec![1.0; WORKERS], Some(&timeline), &opts)?;
    let secs = outcome.runtime.as_secs_f64();

    let d = RemoteData::connect(&addr)?;
    let version = jsdoop::coordinator::version::current_version(&d)?.unwrap_or(0);
    println!("\n--- {name}: {secs:.1}s, final version {version} ---");
    println!("{}", timeline.render_gantt(72));
    handle.shutdown();
    Ok(secs)
}

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.batch_size = 64;
    cfg.examples_per_epoch = 256;
    cfg.epochs = 2;
    cfg.visibility_timeout_secs = 10.0;
    cfg.task_poll_timeout_secs = 0.1;
    cfg.validate()?;
    let engine = Engine::load_shared(&cfg.artifact_dir)?;
    println!("classroom demo over TCP, {WORKERS} volunteers, scaled schedule");

    // Scenario 1: async-start (trickle in over 2s).
    let mut rng = Rng::new(7);
    let async_plan = FaultPlan::async_start(WORKERS, 2.0, &mut rng);
    let t_async = scenario("scenario 1: async-start", &engine, &cfg, &async_plan)?;

    // Scenario 2: sync-start.
    let sync_plan = FaultPlan::sync_start(WORKERS);
    let t_sync = scenario("scenario 2: sync-start", &engine, &cfg, &sync_plan)?;

    // Scenario 3: half close their tab at t=2s.
    let churn_plan = FaultPlan::departure(WORKERS, WORKERS / 2, 0.3);
    let t_churn = scenario("scenario 3: half leave at 0.3s", &engine, &cfg, &churn_plan)?;

    println!("\n=== classroom summary ===");
    println!("  async-start : {t_async:.1}s");
    println!("  sync-start  : {t_sync:.1}s");
    println!("  churn(half) : {t_churn:.1}s");
    println!("(paper shape: sync <= async; churn completes correctly, slower)");
    Ok(())
}
