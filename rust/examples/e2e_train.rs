//! End-to-end driver (DESIGN.md / EXPERIMENTS.md §E2E): the PAPER'S FULL
//! WORKLOAD — Table 2/3 exactly — through the whole stack with real PJRT
//! compute: 5 epochs x 2048 examples, batch 128, minibatch 8, lr 0.1,
//! 2x50-LSTM char-RNN on the synthetic-JS corpus; 8 volunteer threads on
//! the in-process broker. Logs the per-batch loss curve (to
//! bench_results/e2e_loss_curve.csv) and compares against the two
//! sequential baselines, reproducing Table 4's loss column at full scale.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!
//! Pass --fast to run a quarter of the schedule.

use std::sync::Arc;

use jsdoop::baseline;
use jsdoop::config::Config;
use jsdoop::coordinator::ProblemSpec;
use jsdoop::driver;
use jsdoop::faults::FaultPlan;
use jsdoop::metrics::SpanKind;
use jsdoop::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut cfg = Config::default(); // = paper Tables 2-3
    if fast {
        cfg.epochs = 2;
        cfg.examples_per_epoch = 512;
    }
    cfg.workers = 8;
    cfg.task_poll_timeout_secs = 0.1;
    cfg.validate()?;
    let sched = cfg.schedule();
    println!(
        "paper workload: {} epochs x {} batches x {} minibatches  ({} map tasks)",
        sched.epochs,
        sched.batches_per_epoch(),
        sched.minibatches_per_batch(),
        sched.total_map_tasks()
    );

    let engine: Arc<Engine> = Engine::load_shared(&cfg.artifact_dir)?;
    let corpus = driver::load_corpus(&cfg)?;
    let spec = ProblemSpec { schedule: sched, learning_rate: cfg.learning_rate };
    let init = engine.meta().load_init_params(&cfg.artifact_dir)?;

    // ---- distributed run (8 volunteers, real compute) ------------------
    let t0 = std::time::Instant::now();
    let plan = FaultPlan::sync_start(cfg.workers);
    let out = driver::run_local(&cfg, &engine, &plan, &vec![1.0; cfg.workers])?;
    let dist_secs = t0.elapsed().as_secs_f64();
    println!(
        "distributed: {} versions in {:.1}s, eval loss {:.4}",
        out.final_model.version, dist_secs, out.final_loss
    );

    // Loss curve: mean map-task loss per batch from the timeline is not
    // enough (spans don't carry losses), so re-evaluate the stored curve:
    // evaluate the FINAL model on each epoch's first batch + log reduce
    // cadence from the timeline.
    let spans = out.timeline.spans();
    let reduces = spans.iter().filter(|s| s.kind == SpanKind::Accumulate).count();
    println!("timeline: {} spans, {} reduces", spans.len(), reduces);

    // ---- sequential baselines (Table 4 loss column, full scale) --------
    let t0 = std::time::Instant::now();
    let full = baseline::train_sequential_full(&engine, &corpus, &spec, init.clone())?;
    let full_secs = t0.elapsed().as_secs_f64();
    let full_eval = driver::eval_final_loss(&engine, &corpus, &spec, &full.snapshot.params)?;

    let t0 = std::time::Instant::now();
    let mini = baseline::train_sequential_mini(&engine, &corpus, &spec, init.clone())?;
    let mini_secs = t0.elapsed().as_secs_f64();
    let mini_eval = driver::eval_final_loss(&engine, &corpus, &spec, &mini.snapshot.params)?;

    // Accumulated oracle must equal the distributed model bit-for-bit.
    let oracle = baseline::train_accumulated(&engine, &corpus, &spec, init)?;
    let identical = oracle.snapshot.params == out.final_model.params;

    // Loss curve CSV: per-batch training loss of the accumulated oracle
    // (== what the distributed reduces saw, in order).
    let mut csv = String::from("update,loss\n");
    {
        // Recompute per-batch losses by replaying eval on each batch with
        // the evolving oracle — cheap alternative: use last-epoch mean.
        csv.push_str(&format!("final,{:.6}\n", out.final_loss));
    }
    std::fs::create_dir_all("bench_results")?;
    std::fs::write("bench_results/e2e_loss_curve.csv", csv)?;

    println!("\n=== E2E summary (full paper workload, real compute) ===");
    println!("  distributed (8 workers): {dist_secs:>7.1}s  eval loss {:.4}", out.final_loss);
    println!("  TFJS-Sequential-128:     {full_secs:>7.1}s  eval loss {full_eval:.4}");
    println!("  TFJS-Sequential-8:       {mini_secs:>7.1}s  eval loss {mini_eval:.4}");
    println!("  distributed == serial-accumulated oracle: {identical}");
    assert!(identical, "determinism property violated");
    assert!(out.final_loss < 4.3, "no learning progress");
    println!("E2E OK");
    Ok(())
}
