//! Quickstart: the smallest end-to-end JSDoop run.
//!
//! Spins up an in-process QueueServer + DataServer, publishes a scaled
//! char-RNN training problem, runs 4 volunteer threads with real PJRT
//! compute, and prints the resulting loss.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use jsdoop::config::Config;
use jsdoop::driver;
use jsdoop::faults::FaultPlan;
use jsdoop::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. Configuration: paper defaults, scaled down to run in seconds.
    let mut cfg = Config::default();
    cfg.batch_size = 64; // 8 map tasks per batch
    cfg.examples_per_epoch = 256; // 4 batches per epoch
    cfg.epochs = 2;
    cfg.workers = 4;
    cfg.validate()?;

    // 2. The compute engine: AOT-compiled JAX/Pallas artifacts on PJRT.
    let engine: Arc<Engine> = Engine::load_shared(&cfg.artifact_dir)?;
    println!("engine ready on {} ({} params)", engine.platform(), engine.meta().num_params);

    // 3. Run: Initiator publishes tasks; volunteers pull, compute, ACK.
    let plan = FaultPlan::sync_start(cfg.workers);
    let out = driver::run_local(&cfg, &engine, &plan, &vec![1.0; cfg.workers])?;

    println!(
        "trained {} model versions in {:.1}s across {} volunteers",
        out.final_model.version,
        out.pool.runtime.as_secs_f64(),
        cfg.workers
    );
    println!("final eval loss: {:.4} (ln(98) = 4.585 is chance)", out.final_loss);
    for (i, r) in out.pool.reports.iter().enumerate() {
        println!(
            "  volunteer {i}: {} maps, {} reduces, {} swaps",
            r.maps_done, r.reduces_done, r.tasks_swapped
        );
    }
    Ok(())
}
