//! Cluster sweep (paper §V.A, Figs 4-6 in one shot): run the calibrated
//! discrete-event cluster profile for 1..32 workers and print the
//! runtime / relative speedup / relative efficiency triple — the quick
//! way to eyeball the paper's headline scaling result.
//!
//!     cargo run --release --example cluster_sweep [--seed=N]

use jsdoop::metrics::{efficiency, speedup};
use jsdoop::profiles;
use jsdoop::util::prng::Rng;
use jsdoop::volunteer::sim::{simulate, SimWorkload};

fn main() -> anyhow::Result<()> {
    let seed: u64 = std::env::args()
        .find_map(|a| a.strip_prefix("--seed=").map(|v| v.parse().ok()).flatten())
        .unwrap_or(42);
    println!("cluster sweep, paper workload (80 batches x 16 minibatches), seed {seed}");
    println!(
        "{:>8} | {:>14} | {:>9} | {:>10} | {:>10}",
        "workers", "runtime (min)", "speedup", "efficiency", "cache hit"
    );
    let mut t1 = None;
    for w in [1usize, 2, 4, 8, 16, 32] {
        let mut rng = Rng::new(seed);
        let (params, speeds, plan) = profiles::cluster(w, &mut rng);
        let r = simulate(SimWorkload::paper(), &params, &plan, &speeds, seed)?;
        let base = *t1.get_or_insert(r.runtime);
        println!(
            "{w:>8} | {:>14.1} | {:>9.2} | {:>10.2} | {:>10.2}",
            r.runtime / 60.0,
            speedup(base, r.runtime),
            efficiency(base, r.runtime, w),
            r.cache_hit_rate
        );
    }
    println!("\n(expect: superlinear speedup 2..16 — slow-first node fill + cache");
    println!(" thrash at 1 worker — then the 16-minibatch sync wall at 32)");
    Ok(())
}
