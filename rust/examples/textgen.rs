//! Text generation demo: train the paper's char-RNN briefly with JSDoop,
//! then sample text from it through the `predict_b1` artifact — the fun
//! half of the TF.js lstm-text-generation example the paper builds on.
//!
//!     make artifacts && cargo run --release --example textgen

use std::sync::Arc;

use jsdoop::config::Config;
use jsdoop::driver;
use jsdoop::faults::FaultPlan;
use jsdoop::runtime::Engine;
use jsdoop::textdata::id_to_char;
use jsdoop::util::prng::Rng;

fn sample(probs: &[f32], rng: &mut Rng, temperature: f32) -> usize {
    // Temperature-adjusted categorical sample.
    let logits: Vec<f64> = probs
        .iter()
        .map(|p| (p.max(1e-9) as f64).ln() / temperature as f64)
        .collect();
    let m = logits.iter().cloned().fold(f64::MIN, f64::max);
    let exps: Vec<f64> = logits.iter().map(|l| (l - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    let r = rng.f64() * z;
    let mut cum = 0.0;
    for (i, e) in exps.iter().enumerate() {
        cum += e;
        if cum >= r {
            return i;
        }
    }
    exps.len() - 1
}

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.epochs = 2;
    cfg.examples_per_epoch = 1024;
    cfg.workers = 8;
    cfg.task_poll_timeout_secs = 0.1;
    cfg.validate()?;

    let engine: Arc<Engine> = Engine::load_shared(&cfg.artifact_dir)?;
    let corpus = driver::load_corpus(&cfg)?;

    println!("training char-RNN with {} volunteers...", cfg.workers);
    let out = driver::run_local(
        &cfg,
        &engine,
        &FaultPlan::sync_start(cfg.workers),
        &vec![1.0; cfg.workers],
    )?;
    println!(
        "trained to version {} (loss {:.3}) in {:.1}s",
        out.final_model.version,
        out.final_loss,
        out.pool.runtime.as_secs_f64()
    );

    // Seed window from the corpus, then free-run the model.
    let t = engine.meta().seq_len;
    let seed_text = corpus.decode(0, t);
    let mut window: Vec<i32> = corpus.ids()[..t].iter().map(|&c| c as i32).collect();
    let mut rng = Rng::new(7);
    for temperature in [0.5f32, 1.0] {
        let mut generated = String::new();
        let mut w = window.clone();
        for _ in 0..300 {
            let probs = engine.predict(&out.final_model.params, &w)?;
            let next = sample(&probs, &mut rng, temperature);
            generated.push(id_to_char(next as u8) as char);
            w.remove(0);
            w.push(next as i32);
        }
        println!("\n--- temperature {temperature} ---");
        println!("seed: {seed_text:?}");
        println!("{generated}");
    }
    window.clear();
    Ok(())
}
